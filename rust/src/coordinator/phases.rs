//! Instrumented execution: wraps the functional engine's kernel dispatch
//! so every real tiny-model inference also produces (a) measured host
//! wall-time per phase and (b) the modeled IMAX phase costs for the same
//! kernel sequence — tying the functional and timing paths together (the
//! quickstart example prints both side by side).
//!
//! The wrapper is a **plan/submit** backend: each dispatched kernel is
//! costed and recorded into a [`LaunchQueue`] rather than summed on the
//! spot, and the queue flushes at the engine's
//! [`KernelExec::submit`]/[`KernelExec::sync`] points. With the
//! double-buffered prefetch model disabled the flush replays the queue
//! eagerly — cost accounting bit-identical to the old per-call path. With
//! it enabled (`--backend imax:dbuf`), each queued kernel's streaming
//! LOAD portion is overlapped with the *previous* kernel's EXEC inside
//! the same submission batch (capped by the DMA [`TransferMode`]'s
//! effective bandwidth — [`crate::imax::dma::load_stream_seconds`]),
//! quantifying how much of the paper's transfer bottleneck the
//! double-buffered LMM recovers.
//!
//! Ubatch dispatches ([`MatvecExec::linear_ubatch`]) are accounted with
//! the chunk size as the cost model's batch factor, so a batched prefill
//! amortizes the weight transfer and per-kernel configuration exactly the
//! way `coordinator::hybrid` models it (prefill compute-bound, decode
//! LOAD-bound — paper §V.B).

use std::time::Instant;

use crate::coordinator::offload::{OffloadPolicy, OffloadStats};
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::pio::ConfTracker;
use crate::imax::sim;
use crate::imax::timing::{PhaseCost, RunBreakdown};
use crate::model::engine::{KernelExec, MatvecExec, RoundBalance};
use crate::model::graph::{KvSwapDir, MatvecOp, OpKind, Phase};
use crate::runtime::queue::{KernelOp, LaunchQueue};
use crate::tensor::{ActQuant, QTensor};

/// Cost annotation attached to each queued launch.
#[derive(Clone, Copy, Debug)]
struct LaunchCost {
    phase: Phase,
    cost: PhaseCost,
    /// Streaming portion of `cost.load` a double-buffered prefetch can
    /// hide under the previous kernel's EXEC (0 for host-run kernels).
    load_stream: f64,
}

/// Per-round modeled cost delta, snapshotted at each
/// [`KernelExec::round_boundary`] the iteration scheduler marks: what
/// one token-budgeted round (live decode tokens + resumable prefill
/// chunks) added to the modeled totals. The streamed bytes are the
/// paper's transfer-bottleneck quantity — a round that carries a large
/// prefill chunk shows up directly as a byte/LOAD spike here.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundCost {
    /// Modeled seconds the round added (all phases, LOAD/EXEC/HOST/…).
    pub modeled_s: f64,
    /// Modeled streaming-LOAD seconds the round added (post-overlap:
    /// what the double-buffered prefetch could not hide).
    pub load_s: f64,
    /// Modeled kernel-EXEC seconds the round added.
    pub exec_s: f64,
    /// Operand bytes the round's offloaded kernels streamed host→LMM.
    pub streamed_bytes: u64,
}

impl RoundCost {
    /// The round's LOAD/EXEC split as the scheduler feedback signal
    /// ([`crate::model::engine::KernelExec::last_round_balance`]).
    pub fn balance(&self) -> RoundBalance {
        RoundBalance { load_s: self.load_s, exec_s: self.exec_s }
    }
}

/// A [`MatvecExec`] that runs kernels through an inner executor while
/// accumulating modeled IMAX costs, offload statistics, and measured
/// wall time per phase. Costs queue per launch and settle at the
/// engine's submit points (see the module docs).
pub struct InstrumentedExec<E: MatvecExec> {
    /// The executor that actually runs the kernels.
    pub inner: E,
    /// IMAX device model pricing every queued launch.
    pub dev: ImaxDevice,
    /// Offload decision policy (what runs on the accelerator).
    pub policy: OffloadPolicy,
    /// DMA transfer mode the cost model charges (PIO vs coalesced).
    pub mode: TransferMode,
    /// Model the double-buffered LMM prefetch: overlap each queued
    /// kernel's streaming LOAD with the previous kernel's EXEC within a
    /// submission batch.
    pub overlap: bool,
    /// Accumulated modeled per-phase costs.
    pub modeled: RunBreakdown,
    /// Offloaded / total MAC accounting.
    pub stats: OffloadStats,
    /// Modeled LOAD seconds recovered by prefetch overlap (0 with
    /// `overlap` off).
    pub overlap_saved_s: f64,
    /// KV page swap traffic observed through [`MatvecExec::kv_transfer`]
    /// (prefix-cache eviction/restore), in the pool's page encoding —
    /// f16 bytes or q8_0 block bytes, whichever `--kv-quant` selected.
    /// The modeled seconds are already folded into `modeled` via
    /// [`sim::kv_swap_cost`].
    pub kv_swap_bytes: u64,
    /// Modeled seconds the swap traffic cost (LOAD + DRAIN + HOST).
    pub kv_swap_s: f64,
    /// Operand bytes (weights + activations) the offloaded kernels
    /// streamed host→LMM — the paper's bottleneck quantity. Prefix hits
    /// shrink this directly: skipped prefill tokens never dispatch, so
    /// their kernels' bytes never stream (`benches/prefix_reuse.rs`
    /// reports the reduction).
    pub streamed_bytes: u64,
    /// Measured wall seconds spent in prefill steps.
    pub wall_prefill: f64,
    /// Measured wall seconds spent in decode steps.
    pub wall_decode: f64,
    /// Modeled cost deltas per scheduler round
    /// ([`KernelExec::round_boundary`]); empty unless an iteration
    /// scheduler marks rounds on this executor (the continuous batcher
    /// marks every settled round, budgeted or not).
    pub rounds: Vec<RoundCost>,
    tracker: ConfTracker,
    queue: LaunchQueue<LaunchCost>,
    current_phase: Phase,
    step_start: Option<Instant>,
    /// Cumulative modeled seconds at the last round boundary.
    round_mark_modeled_s: f64,
    /// Cumulative modeled LOAD / EXEC seconds at the last round boundary.
    round_mark_load_s: f64,
    round_mark_exec_s: f64,
    /// Cumulative streamed bytes at the last round boundary.
    round_mark_bytes: u64,
}

impl<E: MatvecExec> InstrumentedExec<E> {
    /// Wrap `inner` with cost instrumentation for the given device
    /// model, offload policy and transfer mode.
    pub fn new(inner: E, dev: ImaxDevice, policy: OffloadPolicy, mode: TransferMode) -> Self {
        InstrumentedExec {
            inner,
            dev,
            policy,
            mode,
            overlap: false,
            modeled: RunBreakdown::default(),
            stats: OffloadStats::default(),
            overlap_saved_s: 0.0,
            kv_swap_bytes: 0,
            kv_swap_s: 0.0,
            streamed_bytes: 0,
            wall_prefill: 0.0,
            wall_decode: 0.0,
            rounds: Vec::new(),
            tracker: ConfTracker::new(),
            queue: LaunchQueue::new(),
            current_phase: Phase::Prefill,
            step_start: None,
            round_mark_modeled_s: 0.0,
            round_mark_load_s: 0.0,
            round_mark_exec_s: 0.0,
            round_mark_bytes: 0,
        }
    }

    /// Enable/disable the double-buffered prefetch overlap model.
    pub fn with_overlap(mut self, overlap: bool) -> Self {
        self.overlap = overlap;
        self
    }

    /// Cost one kernel instance processing `batch` activation vectors
    /// against the same weights (batch > 1 for prefill ubatches) and
    /// record it into the launch queue; the modeled totals settle at the
    /// next flush.
    fn account(&mut self, op: &MatvecOp, batch: usize) {
        let offloaded = self.policy.should_offload(&self.dev, op);
        if offloaded {
            self.streamed_bytes += (op.weight_bytes() + op.act_bytes() * batch) as u64;
        }
        let (cost, load_stream) = if offloaded {
            let k = sim::offloaded_cost_parts(
                &self.dev,
                &self.policy.lmm,
                &mut self.tracker,
                op,
                batch,
                self.mode,
            );
            (k.cost, k.load_stream)
        } else {
            (sim::host_cost(&self.dev, op, batch), 0.0)
        };
        for _ in 0..batch {
            self.stats.record(op, offloaded);
        }
        let kop = match op.kind {
            OpKind::AttnScore | OpKind::AttnMix => KernelOp::Attn { op: op.clone() },
            OpKind::Linear(_) => KernelOp::Linear { op: op.clone(), batch },
        };
        let phase = self.current_phase;
        self.queue.record(kop, LaunchCost { phase, cost, load_stream });
    }

    /// Flush one submission batch into the modeled totals, in record
    /// (FIFO) order. With `overlap` on, kernel *k*'s streaming LOAD hides
    /// under kernel *k−1*'s EXEC; step markers reset the window.
    fn flush(&mut self) {
        let mut prev_exec = 0.0f64;
        for l in self.queue.submit() {
            if !l.op.is_kernel() {
                prev_exec = 0.0;
                continue;
            }
            let mut cost = l.payload.cost;
            if self.overlap {
                let hidden = prev_exec.min(l.payload.load_stream).min(cost.load);
                cost.load -= hidden;
                self.overlap_saved_s += hidden;
            }
            prev_exec = cost.exec;
            self.modeled.add(l.payload.phase, cost);
        }
    }
}

impl<E: MatvecExec> MatvecExec for InstrumentedExec<E> {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        self.account(op, 1);
        self.inner.linear(op, w, act, out);
    }

    fn linear_ubatch(&mut self, op: &MatvecOp, w: &QTensor, acts: &[ActQuant], outs: &mut [f32]) {
        // One modeled launch for the whole chunk: the weight transfer and
        // configuration amortize across `acts.len()` activation vectors.
        // Dispatch through the inner executor's own ubatch hook so a
        // batching backend keeps its amortization under instrumentation.
        self.account(op, acts.len());
        self.inner.linear_ubatch(op, w, acts, outs);
    }

    fn attn(&mut self, op: &MatvecOp) {
        self.account(op, 1);
        self.inner.attn(op);
    }

    fn kv_transfer(&mut self, phase: Phase, dir: KvSwapDir, bytes: usize) {
        // Swap traffic is host-issued DMA outside the kernel launch
        // stream: charge it straight into the modeled totals through the
        // same TransferMode the kernels use, so oversubscribed serving
        // shows up in the LOAD/DRAIN bottleneck it actually stresses.
        let cost = sim::kv_swap_cost(&self.dev, bytes, dir, self.mode);
        self.kv_swap_bytes += bytes as u64;
        self.kv_swap_s += cost.total();
        self.modeled.add(phase, cost);
        self.inner.kv_transfer(phase, dir, bytes);
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        self.current_phase = phase;
        self.queue.record(
            KernelOp::BeginStep { phase, pos },
            LaunchCost { phase, cost: PhaseCost::ZERO, load_stream: 0.0 },
        );
        self.step_start = Some(Instant::now());
        self.inner.begin_step(phase, pos);
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        self.queue.record(
            KernelOp::EndStep { phase, pos },
            LaunchCost { phase, cost: PhaseCost::ZERO, load_stream: 0.0 },
        );
        // Implicit sync: a step boundary never leaves launches pending,
        // so reports read complete totals even if a driver skips sync().
        self.flush();
        if let Some(t0) = self.step_start.take() {
            let dt = t0.elapsed().as_secs_f64();
            match phase {
                Phase::Prefill => self.wall_prefill += dt,
                Phase::Decode => self.wall_decode += dt,
            }
        }
        self.inner.end_step(phase, pos);
    }
}

impl<E: MatvecExec> KernelExec for InstrumentedExec<E> {
    fn submit(&mut self) {
        self.flush();
    }

    fn round_boundary(&mut self) {
        // Settle anything still queued, then snapshot what this round
        // added to the modeled totals — the per-round view of the
        // transfer bottleneck.
        self.flush();
        let tot = self.modeled.total();
        let cum = tot.total();
        self.rounds.push(RoundCost {
            modeled_s: cum - self.round_mark_modeled_s,
            load_s: tot.load - self.round_mark_load_s,
            exec_s: tot.exec - self.round_mark_exec_s,
            streamed_bytes: self.streamed_bytes - self.round_mark_bytes,
        });
        self.round_mark_modeled_s = cum;
        self.round_mark_load_s = tot.load;
        self.round_mark_exec_s = tot.exec;
        self.round_mark_bytes = self.streamed_bytes;
    }

    fn last_round_balance(&self) -> Option<RoundBalance> {
        self.rounds.last().map(RoundCost::balance)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imax::lmm::LmmConfig;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::{Engine, NativeExec};
    use crate::model::sampler::Sampler;
    use crate::model::weights::ModelWeights;

    fn fpga_instrumented() -> InstrumentedExec<NativeExec> {
        InstrumentedExec::new(
            NativeExec,
            ImaxDevice::fpga(2),
            OffloadPolicy::new(LmmConfig::new(64)),
            TransferMode::Coalesced,
        )
    }

    #[test]
    fn instrumentation_tracks_real_generation() {
        let cfg = ModelConfig::tiny();
        let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 3));
        let mut exec = fpga_instrumented();
        let res = engine.generate(&[1, 2, 3, 4], 4, &mut Sampler::greedy(), &mut exec);
        assert_eq!(res.tokens.len(), 4);
        // 4-token prefill ubatch + 3 decode steps, each with linears +
        // attention.
        assert!(exec.modeled.prefill.total() > 0.0);
        assert!(exec.modeled.decode.total() > 0.0);
        assert!(exec.wall_prefill > 0.0);
        assert!(exec.wall_decode > 0.0);
        assert!(exec.stats.total_ratio() > 0.0);
        // Step boundaries drained the queue: nothing pending after a run.
        assert_eq!(exec.overlap_saved_s, 0.0, "overlap off by default");
    }

    #[test]
    fn instrumented_results_match_native() {
        let cfg = ModelConfig::tiny();
        let mut e1 = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q3KS, 5));
        let mut e2 = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q3KS, 5));
        let mut inst = fpga_instrumented();
        let a = e1.generate(&[7, 8, 9], 5, &mut Sampler::greedy(), &mut NativeExec);
        let b = e2.generate(&[7, 8, 9], 5, &mut Sampler::greedy(), &mut inst);
        assert_eq!(a.tokens, b.tokens, "instrumentation must not alter results");
    }

    #[test]
    fn ubatch_accounting_amortizes_prefill() {
        // The same 8-token prompt, prefilled as one ubatch vs one token
        // at a time: identical compute, but the batched run amortizes
        // weight LOAD and configuration, so its modeled prefill must be
        // strictly cheaper.
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 9);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];

        let mut batched = Engine::new(weights.clone());
        let mut exec_b = fpga_instrumented();
        let sess = batched.open_session(Sampler::greedy()).unwrap();
        batched.prefill_session(&sess, &prompt, prompt.len(), &mut exec_b);

        let mut seq = Engine::new(weights);
        let mut exec_s = fpga_instrumented();
        for (i, &t) in prompt.iter().enumerate() {
            seq.forward(t, Phase::Prefill, i + 1 == prompt.len(), &mut exec_s);
        }

        let b = exec_b.modeled.prefill;
        let s = exec_s.modeled.prefill;
        assert!(
            b.load < s.load,
            "batched LOAD {} must beat sequential {}",
            b.load,
            s.load
        );
        assert!(b.total() < s.total(), "batched prefill cheaper overall");
        // Same kernels were executed either way.
        assert!((exec_b.stats.total_ratio() - exec_s.stats.total_ratio()).abs() < 1e-9);
    }

    #[test]
    fn round_boundary_snapshots_cost_deltas() {
        // Two marked rounds: the per-round deltas must reconcile exactly
        // with the cumulative modeled totals and streamed bytes.
        let cfg = ModelConfig::tiny();
        let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 3));
        let mut exec = fpga_instrumented();
        engine.forward(1, Phase::Prefill, true, &mut exec);
        exec.round_boundary();
        engine.forward(2, Phase::Decode, true, &mut exec);
        engine.forward(3, Phase::Decode, true, &mut exec);
        exec.round_boundary();
        assert_eq!(exec.rounds.len(), 2);
        assert!(exec.rounds.iter().all(|r| r.modeled_s > 0.0 && r.streamed_bytes > 0));
        let total: f64 = exec.rounds.iter().map(|r| r.modeled_s).sum();
        assert!(
            (total - exec.modeled.total().total()).abs() < 1e-12,
            "round deltas reconcile with the cumulative totals"
        );
        let bytes: u64 = exec.rounds.iter().map(|r| r.streamed_bytes).sum();
        assert_eq!(bytes, exec.streamed_bytes);
        // The LOAD/EXEC split reconciles the same way, and the feedback
        // accessor hands the scheduler the last round's balance.
        let load: f64 = exec.rounds.iter().map(|r| r.load_s).sum();
        let ex: f64 = exec.rounds.iter().map(|r| r.exec_s).sum();
        assert!((load - exec.modeled.total().load).abs() < 1e-12);
        assert!((ex - exec.modeled.total().exec).abs() < 1e-12);
        let bal = exec.last_round_balance().expect("instrumented backend feeds balance");
        assert_eq!(bal, exec.rounds[1].balance());
        assert!(bal.load_fraction().expect("offloaded round has LOAD+EXEC") > 0.0);
    }

    #[test]
    fn dbuf_overlap_recovers_load_without_touching_exec() {
        // The same run with and without the double-buffered prefetch
        // model: overlap hides LOAD (never EXEC), strictly lowering both
        // modeled phases, and the saved seconds reconcile exactly.
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 13);
        let run = |overlap: bool| {
            let mut engine = Engine::new(weights.clone());
            let mut exec = fpga_instrumented().with_overlap(overlap);
            let res = engine.generate(&[1, 2, 3, 4], 5, &mut Sampler::greedy(), &mut exec);
            (res.tokens, exec)
        };
        let (t_off, off) = run(false);
        let (t_on, on) = run(true);
        assert_eq!(t_off, t_on, "a cost model must not change tokens");
        assert_eq!(off.overlap_saved_s, 0.0);
        assert!(on.overlap_saved_s > 0.0, "prefetch hid some LOAD");
        // EXEC identical, LOAD strictly lower in both phases.
        assert_eq!(on.modeled.prefill.exec, off.modeled.prefill.exec);
        assert_eq!(on.modeled.decode.exec, off.modeled.decode.exec);
        assert!(on.modeled.prefill.load < off.modeled.prefill.load);
        assert!(on.modeled.decode.load < off.modeled.decode.load);
        assert!(on.modeled.decode.total() < off.modeled.decode.total());
        // Saved seconds account for the whole difference.
        let diff = off.modeled.total().total() - on.modeled.total().total();
        assert!(
            (diff - on.overlap_saved_s).abs() < 1e-9,
            "diff {diff} vs saved {}",
            on.overlap_saved_s
        );
    }
}
