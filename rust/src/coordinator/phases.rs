//! Instrumented execution: wraps the functional engine's kernel dispatch
//! so every real tiny-model inference also produces (a) measured host
//! wall-time per phase and (b) the modeled IMAX phase costs for the same
//! kernel sequence — tying the functional and timing paths together (the
//! quickstart example prints both side by side).

use std::time::Instant;

use crate::coordinator::offload::{OffloadPolicy, OffloadStats};
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::pio::ConfTracker;
use crate::imax::sim;
use crate::imax::timing::RunBreakdown;
use crate::model::engine::MatvecExec;
use crate::model::graph::{MatvecOp, Phase};
use crate::tensor::{ActQuant, QTensor};

/// A [`MatvecExec`] that runs kernels through an inner executor while
/// accumulating modeled IMAX costs, offload statistics, and measured
/// wall time per phase.
pub struct InstrumentedExec<'a, E: MatvecExec> {
    pub inner: E,
    pub dev: &'a ImaxDevice,
    pub policy: &'a OffloadPolicy,
    pub mode: TransferMode,
    pub modeled: RunBreakdown,
    pub stats: OffloadStats,
    pub wall_prefill: f64,
    pub wall_decode: f64,
    tracker: ConfTracker,
    current_phase: Phase,
    step_start: Option<Instant>,
}

impl<'a, E: MatvecExec> InstrumentedExec<'a, E> {
    pub fn new(
        inner: E,
        dev: &'a ImaxDevice,
        policy: &'a OffloadPolicy,
        mode: TransferMode,
    ) -> Self {
        InstrumentedExec {
            inner,
            dev,
            policy,
            mode,
            modeled: RunBreakdown::default(),
            stats: OffloadStats::default(),
            wall_prefill: 0.0,
            wall_decode: 0.0,
            tracker: ConfTracker::new(),
            current_phase: Phase::Prefill,
            step_start: None,
        }
    }

    fn account(&mut self, op: &MatvecOp) {
        let offloaded = self.policy.should_offload(self.dev, op);
        let cost = if offloaded {
            sim::offloaded_cost(
                self.dev,
                &self.policy.lmm,
                &mut self.tracker,
                op,
                1,
                self.mode,
            )
        } else {
            sim::host_cost(self.dev, op, 1)
        };
        self.modeled.add(self.current_phase, cost);
        self.stats.record(op, offloaded);
    }
}

impl<'a, E: MatvecExec> MatvecExec for InstrumentedExec<'a, E> {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        self.account(op);
        self.inner.linear(op, w, act, out);
    }

    fn attn(&mut self, op: &MatvecOp) {
        self.account(op);
        self.inner.attn(op);
    }

    fn begin_step(&mut self, phase: Phase, pos: usize) {
        self.current_phase = phase;
        self.step_start = Some(Instant::now());
        self.inner.begin_step(phase, pos);
    }

    fn end_step(&mut self, phase: Phase, pos: usize) {
        if let Some(t0) = self.step_start.take() {
            let dt = t0.elapsed().as_secs_f64();
            match phase {
                Phase::Prefill => self.wall_prefill += dt,
                Phase::Decode => self.wall_decode += dt,
            }
        }
        self.inner.end_step(phase, pos);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imax::lmm::LmmConfig;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::{Engine, NativeExec};
    use crate::model::sampler::Sampler;
    use crate::model::weights::ModelWeights;

    #[test]
    fn instrumentation_tracks_real_generation() {
        let cfg = ModelConfig::tiny();
        let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 3));
        let dev = ImaxDevice::fpga(2);
        let policy = OffloadPolicy::new(LmmConfig::new(64));
        let mut exec =
            InstrumentedExec::new(NativeExec, &dev, &policy, TransferMode::Coalesced);
        let res = engine.generate(&[1, 2, 3, 4], 4, &mut Sampler::greedy(), &mut exec);
        assert_eq!(res.tokens.len(), 4);
        // 4 prefill + 3 decode steps, each with linears + attention.
        assert!(exec.modeled.prefill.total() > 0.0);
        assert!(exec.modeled.decode.total() > 0.0);
        assert!(exec.wall_prefill > 0.0);
        assert!(exec.wall_decode > 0.0);
        assert!(exec.stats.total_ratio() > 0.0);
    }

    #[test]
    fn instrumented_results_match_native() {
        let cfg = ModelConfig::tiny();
        let mut e1 = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q3KS, 5));
        let mut e2 = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q3KS, 5));
        let dev = ImaxDevice::fpga(2);
        let policy = OffloadPolicy::new(LmmConfig::new(64));
        let mut inst =
            InstrumentedExec::new(NativeExec, &dev, &policy, TransferMode::Coalesced);
        let a = e1.generate(&[7, 8, 9], 5, &mut Sampler::greedy(), &mut NativeExec);
        let b = e2.generate(&[7, 8, 9], 5, &mut Sampler::greedy(), &mut inst);
        assert_eq!(a.tokens, b.tokens, "instrumentation must not alter results");
    }
}
