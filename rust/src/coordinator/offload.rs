//! Offload policy and offload-ratio accounting (paper §III.A, Table 2).
//!
//! The paper partitions work by "assigning tasks to the most suitable
//! processing unit": dot-product kernels go to IMAX when that is
//! profitable, everything else stays on the host. Two concrete criteria
//! emerge from the paper:
//!
//! 1. **LMM fit** (§V.A) — the kernel's per-burst operand tile must
//!    stream through the configured LMM.
//! 2. **DMA-buffer residency** (§V.C, Table 1 note b) — the VPK180
//!    reserves 4 GB of DDR4 as the DMA staging buffer; a kernel format is
//!    only offloaded if its weight tensors stay resident there ("the
//!    prototype's limited DMA buffer size restricted our experiments").
//!    Qwen3-8B Q8_0 weighs ≈8.5 GB, so its Q8_0 kernels cannot be
//!    offloaded — exactly Table 2's 0% row, and the paper's §V.A
//!    conclusion that avoiding that offload is also the most
//!    energy-efficient strategy. For 8B Q3_K_S (≈4.7 GB of offload
//!    candidates) the *smaller* Q6_K class is shed first, retaining the
//!    bulk of the offload coverage — matching Table 2's Q6_K = 0% row.
//!
//! The ratio Table 2 reports is per-kernel-format: offloaded dot-product
//! invocations / total invocations of that format.

use std::collections::{HashMap, HashSet};

use crate::imax::device::ImaxDevice;
use crate::imax::isa::KernelClass;
use crate::imax::lmm::{self, LmmConfig};
use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
use crate::model::graph::{MatvecOp, OpKind};
use crate::util::report::Table;

/// Offload decision policy for one (model, scheme, device) combination.
#[derive(Clone, Debug)]
pub struct OffloadPolicy {
    /// LMM geometry offload candidates must fit (capacity gate).
    pub lmm: LmmConfig,
    /// Kernel classes excluded because their weights don't fit the DMA
    /// staging buffer.
    pub excluded: HashSet<KernelClass>,
    /// Force-disable offload entirely (host-only baseline runs).
    pub disabled: bool,
}

/// Total weight bytes of each kernel class's offload candidates (linears
/// + LM head; attention operands are activations/KV, not resident
/// weights).
pub fn class_weight_bytes(cfg: &ModelConfig, scheme: QuantScheme) -> HashMap<KernelClass, usize> {
    let mut by_class: HashMap<KernelClass, usize> = HashMap::new();
    for kind in LinearKind::ALL {
        let (rows, cols) = kind.shape(cfg);
        let ty = kind.weight_type(scheme);
        let count = if kind == LinearKind::LmHead {
            1
        } else {
            cfg.n_layers
        };
        *by_class.entry(KernelClass::for_type(ty)).or_insert(0) +=
            count * rows * ty.row_bytes(cols);
    }
    by_class
}

impl OffloadPolicy {
    /// Policy with no DMA-budget exclusions (tiny functional models).
    pub fn new(lmm: LmmConfig) -> OffloadPolicy {
        OffloadPolicy {
            lmm,
            excluded: HashSet::new(),
            disabled: false,
        }
    }

    /// Policy that offloads nothing: the host-only baseline.
    pub fn host_only() -> OffloadPolicy {
        OffloadPolicy {
            lmm: LmmConfig::new(64),
            excluded: HashSet::new(),
            disabled: true,
        }
    }

    /// Build the policy for a paper-scale workload: applies the DMA-buffer
    /// residency rule, shedding the smallest weight classes first (keeps
    /// the most offload coverage — reproduces Table 2's 8B rows).
    pub fn for_workload(
        dev: &ImaxDevice,
        cfg: &ModelConfig,
        scheme: QuantScheme,
        lmm: LmmConfig,
    ) -> OffloadPolicy {
        let by_class = class_weight_bytes(cfg, scheme);
        let mut total: usize = by_class.values().sum();
        let mut excluded = HashSet::new();
        if total > dev.dma_buffer_bytes {
            // Shed smallest classes until the remainder is resident.
            let mut classes: Vec<(KernelClass, usize)> = by_class.into_iter().collect();
            classes.sort_by_key(|&(_, b)| b);
            for (class, bytes) in classes {
                if total <= dev.dma_buffer_bytes {
                    break;
                }
                excluded.insert(class);
                total -= bytes;
            }
        }
        OffloadPolicy {
            lmm,
            excluded,
            disabled: false,
        }
    }

    /// Decide whether to offload `op`.
    pub fn should_offload(&self, _dev: &ImaxDevice, op: &MatvecOp) -> bool {
        if self.disabled {
            return false;
        }
        let class = KernelClass::for_type(op.wty);
        // Attention kernels stream the KV cache (not resident weights) —
        // the DMA-budget exclusion applies only to weight-bearing linears.
        if matches!(op.kind, OpKind::Linear(_)) && self.excluded.contains(&class) {
            return false;
        }
        lmm::fits(op, &self.lmm)
    }
}

/// Per-format offload accounting (dot-product invocations, Table 2's
/// unit), plus MAC-weighted totals.
#[derive(Clone, Debug, Default)]
pub struct OffloadStats {
    /// (offloaded dots, total dots) per kernel class.
    per_class: HashMap<KernelClass, (u64, u64)>,
    /// (offloaded, total) per op kind (diagnostics).
    per_kind: HashMap<String, (u64, u64)>,
    /// MACs executed on the accelerator.
    pub offloaded_macs: u64,
    /// MACs executed anywhere (host + accelerator).
    pub total_macs: u64,
}

impl OffloadStats {
    /// Account one matvec op under the given offload decision.
    pub fn record(&mut self, op: &MatvecOp, offloaded: bool) {
        let class = KernelClass::for_type(op.wty);
        let e = self.per_class.entry(class).or_insert((0, 0));
        e.1 += op.dots();
        if offloaded {
            e.0 += op.dots();
        }
        let k = self
            .per_kind
            .entry(op.kind.name().to_string())
            .or_insert((0, 0));
        k.1 += op.dots();
        if offloaded {
            k.0 += op.dots();
        }
        self.total_macs += op.macs();
        if offloaded {
            self.offloaded_macs += op.macs();
        }
    }

    /// Offload ratio for one kernel format; `None` if the format never
    /// appears (Table 2's "-").
    pub fn ratio(&self, class: KernelClass) -> Option<f64> {
        self.per_class.get(&class).map(|&(off, tot)| {
            if tot == 0 {
                0.0
            } else {
                off as f64 / tot as f64
            }
        })
    }

    /// Total offload ratio over all dot-product invocations.
    pub fn total_ratio(&self) -> f64 {
        let (off, tot) = self
            .per_class
            .values()
            .fold((0u64, 0u64), |(a, b), &(o, t)| (a + o, b + t));
        if tot == 0 {
            0.0
        } else {
            off as f64 / tot as f64
        }
    }

    /// Offload ratio of one linear kind (`None` when never seen).
    pub fn ratio_for_kind(&self, kind: LinearKind) -> Option<f64> {
        self.per_kind
            .get(kind.name())
            .map(|&(off, tot)| if tot == 0 { 0.0 } else { off as f64 / tot as f64 })
    }

    /// Render a Table 2-style row set.
    pub fn table(&self, label: &str) -> Table {
        let mut t = Table::new(
            &format!("offload ratios — {label}"),
            &["kernel", "offloaded", "total", "ratio"],
        );
        let mut classes: Vec<_> = self.per_class.iter().collect();
        classes.sort_by_key(|(c, _)| c.name());
        for (c, &(off, tot)) in classes {
            t.row(vec![
                c.name().to_string(),
                off.to_string(),
                tot.to_string(),
                format!("{:.2}%", 100.0 * off as f64 / tot.max(1) as f64),
            ]);
        }
        t.row(vec![
            "Total".to_string(),
            String::new(),
            String::new(),
            format!("{:.2}%", 100.0 * self.total_ratio()),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{LinearKind, ModelConfig, QuantScheme};
    use crate::model::graph::ops_for_token;

    #[test]
    fn small_model_kernels_offload() {
        let dev = ImaxDevice::asic28(2);
        let cfg = ModelConfig::qwen3_0_6b();
        let p = OffloadPolicy::for_workload(&dev, &cfg, QuantScheme::Q3KS, LmmConfig::new(64));
        assert!(p.excluded.is_empty(), "0.6B Q3_K_S fits the DMA buffer");
        let ops = ops_for_token(&cfg, QuantScheme::Q3KS, 16, true);
        let offloaded = ops.iter().filter(|o| p.should_offload(&dev, o)).count();
        assert_eq!(offloaded, ops.len(), "everything offloads");
    }

    #[test]
    fn qwen8b_q8_linears_stay_on_host() {
        // Table 2: 8B Q8_0 → Q8_0 kernels 0% (8.5 GB > 4 GB DMA buffer).
        let dev = ImaxDevice::asic28(2);
        let cfg = ModelConfig::qwen3_8b();
        let p = OffloadPolicy::for_workload(&dev, &cfg, QuantScheme::Q8_0, LmmConfig::new(64));
        assert!(p.excluded.contains(&KernelClass::Q8_0));
        let ops = ops_for_token(&cfg, QuantScheme::Q8_0, 16, true);
        for op in &ops {
            let is_linear = matches!(op.kind, OpKind::Linear(_));
            assert_eq!(
                p.should_offload(&dev, op),
                !is_linear,
                "{}",
                op.kind.name()
            );
        }
    }

    #[test]
    fn qwen8b_q3ks_sheds_q6k_first() {
        // Table 2: 8B Q3_K_S → Q6_K 0%, Q3_K still offloaded.
        let dev = ImaxDevice::asic28(2);
        let cfg = ModelConfig::qwen3_8b();
        let p = OffloadPolicy::for_workload(&dev, &cfg, QuantScheme::Q3KS, LmmConfig::new(64));
        assert!(p.excluded.contains(&KernelClass::Q6K), "{:?}", p.excluded);
        assert!(!p.excluded.contains(&KernelClass::Q3K));
    }

    #[test]
    fn class_bytes_match_scheme() {
        let cfg = ModelConfig::qwen3_8b();
        let b = class_weight_bytes(&cfg, QuantScheme::Q8_0);
        let q8 = *b.get(&KernelClass::Q8_0).unwrap();
        assert!(q8 as f64 > 8.0e9, "8B Q8_0 ≈ 8.5 GB, got {q8}");
        let b3 = class_weight_bytes(&cfg, QuantScheme::Q3KS);
        assert!(b3.contains_key(&KernelClass::Q3K));
        assert!(b3.contains_key(&KernelClass::Q6K));
    }

    #[test]
    fn host_only_policy_never_offloads() {
        let dev = ImaxDevice::fpga(2);
        let p = OffloadPolicy::host_only();
        let ops = ops_for_token(&ModelConfig::tiny(), QuantScheme::Q8_0, 0, true);
        assert!(ops.iter().all(|o| !p.should_offload(&dev, o)));
    }

    #[test]
    fn stats_ratios() {
        let mut s = OffloadStats::default();
        let cfg = ModelConfig::qwen3_1_7b();
        let ops = ops_for_token(&cfg, QuantScheme::Q8_0, 0, true);
        for (i, op) in ops.iter().enumerate() {
            s.record(op, i % 2 == 0 || op.wty != crate::quant::GgmlType::Q8_0);
        }
        assert!(s.ratio(KernelClass::Q8_0).unwrap() < 1.0);
        assert!(s.total_ratio() > 0.0 && s.total_ratio() <= 1.0);
        assert!(s.ratio(KernelClass::Q3K).is_none(), "no Q3_K in a Q8_0 model");
        assert!(s.ratio_for_kind(LinearKind::QProj).is_some());
        let t = s.table("test");
        assert!(!t.is_empty());
    }
}
