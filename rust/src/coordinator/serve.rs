//! Batched request serving on std threads (no tokio in the vendored set).
//!
//! The serving driver behind `examples/serve_e2e.rs`: a FIFO request
//! queue feeds worker threads, each owning an engine instance built from
//! shared weights (the host side of the paper's system runs one llama.cpp
//! context per Arm core — our workers mirror that). Reports per-request
//! latency and aggregate throughput, the metrics the paper's E2E
//! evaluation is built on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use crate::model::engine::{Engine, NativeExec};
use crate::model::sampler::Sampler;
use crate::model::weights::ModelWeights;
use crate::util::stats::{percentile, Summary};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub n_out: usize,
}

/// Completed request with timing.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: usize,
    pub tokens: Vec<u32>,
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
    pub worker: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub wall_s: f64,
    pub total_tokens: usize,
    pub throughput_tok_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_mean_s: f64,
}

/// Serve a batch of requests over `n_workers` engine workers; blocks until
/// all requests complete.
pub fn serve(
    weights: &ModelWeights,
    requests: Vec<Request>,
    n_workers: usize,
    sampler_seed: u64,
) -> ServeReport {
    assert!(n_workers >= 1);
    let n_req = requests.len();
    let started = Instant::now();

    // FIFO queue with enqueue timestamps.
    let queue: Arc<Mutex<std::collections::VecDeque<(Request, Instant)>>> = Arc::new(
        Mutex::new(requests.into_iter().map(|r| (r, Instant::now())).collect()),
    );
    let (tx, rx) = mpsc::channel::<Completion>();
    let done = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::new();
    for worker in 0..n_workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        let done = Arc::clone(&done);
        let weights = weights.clone();
        handles.push(thread::spawn(move || {
            let mut engine = Engine::new(weights);
            let mut sampler = Sampler::top_k(0.9, 40, sampler_seed + worker as u64);
            loop {
                let item = queue.lock().unwrap().pop_front();
                let Some((req, enq)) = item else { break };
                let t0 = Instant::now();
                let queue_s = (t0 - enq).as_secs_f64();

                engine.reset();
                // Prefill phase timing.
                let mut logits = None;
                let tp0 = Instant::now();
                for (i, &tok) in req.prompt.iter().enumerate() {
                    let last = i + 1 == req.prompt.len();
                    logits = engine.forward(
                        tok,
                        crate::model::graph::Phase::Prefill,
                        last,
                        &mut NativeExec,
                    );
                }
                let prefill_s = tp0.elapsed().as_secs_f64();

                // Decode phase.
                let td0 = Instant::now();
                let mut tokens = Vec::with_capacity(req.n_out);
                for _ in 0..req.n_out {
                    let l = logits.as_ref().expect("logits");
                    let next = sampler.sample(l);
                    tokens.push(next);
                    if tokens.len() == req.n_out {
                        break;
                    }
                    logits = engine.forward(
                        next,
                        crate::model::graph::Phase::Decode,
                        true,
                        &mut NativeExec,
                    );
                }
                let decode_s = td0.elapsed().as_secs_f64();

                tx.send(Completion {
                    id: req.id,
                    tokens,
                    queue_s,
                    prefill_s,
                    decode_s,
                    total_s: t0.elapsed().as_secs_f64() + queue_s,
                    worker,
                })
                .ok();
                done.fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    drop(tx);

    let mut completions: Vec<Completion> = rx.iter().collect();
    for h in handles {
        h.join().expect("worker panicked");
    }
    completions.sort_by_key(|c| c.id);
    assert_eq!(completions.len(), n_req, "all requests completed");

    let wall_s = started.elapsed().as_secs_f64();
    let total_tokens: usize = completions
        .iter()
        .map(|c| c.tokens.len() + 0)
        .sum::<usize>();
    let lats: Vec<f64> = completions.iter().map(|c| c.total_s).collect();
    let summary = Summary::from_slice(&lats);
    ServeReport {
        throughput_tok_s: total_tokens as f64 / wall_s,
        latency_p50_s: percentile(&lats, 50.0),
        latency_p95_s: percentile(&lats, 95.0),
        latency_mean_s: summary.mean(),
        completions,
        wall_s,
        total_tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};

    fn tiny_weights() -> ModelWeights {
        ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 11)
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                prompt: vec![1 + id as u32, 2, 3, 4],
                n_out: 3,
            })
            .collect()
    }

    #[test]
    fn serves_all_requests_single_worker() {
        let rep = serve(&tiny_weights(), reqs(4), 1, 42);
        assert_eq!(rep.completions.len(), 4);
        assert_eq!(rep.total_tokens, 12);
        assert!(rep.throughput_tok_s > 0.0);
        for c in &rep.completions {
            assert_eq!(c.tokens.len(), 3);
            assert!(c.prefill_s > 0.0 && c.decode_s > 0.0);
        }
    }

    #[test]
    fn multi_worker_completes_and_uses_workers() {
        let rep = serve(&tiny_weights(), reqs(6), 2, 42);
        assert_eq!(rep.completions.len(), 6);
        let workers: std::collections::HashSet<usize> =
            rep.completions.iter().map(|c| c.worker).collect();
        assert!(!workers.is_empty() && workers.len() <= 2);
    }

    #[test]
    fn completions_sorted_by_id() {
        let rep = serve(&tiny_weights(), reqs(5), 2, 7);
        let ids: Vec<usize> = rep.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let rep = serve(&tiny_weights(), reqs(8), 2, 9);
        assert!(rep.latency_p50_s <= rep.latency_p95_s);
        assert!(rep.latency_mean_s > 0.0);
    }
}
