//! Continuous-batching request serving on std threads (no tokio in the
//! vendored set).
//!
//! The serving driver behind `examples/serve_e2e.rs` and `imax-llm
//! serve`: a shared admission queue feeds worker threads, each owning a
//! multi-session engine driven by a [`ContinuousBatcher`] — prefill runs
//! as ubatch chunks and decode rounds interleave every live request, so
//! a request admitted mid-run starts decoding while earlier requests are
//! still generating. Each worker's KV cache is paged
//! (`--page-size`/`--kv-pages`): admission gates on the free-page budget
//! rather than slot count alone, deferred requests return to the queue,
//! and a request whose worst case can never fit the pool completes
//! with [`Completion::error`] set instead of wedging the queue.
//!
//! **Streaming, cancellation, deadlines**: [`serve_streaming`] returns
//! a live [`TokenEvent`] receiver — every token of every request is
//! pushed the moment the scheduler delivers it (the SSE
//! `{content, done}` shape), and all TTFT/TBT marks are stamped at that
//! delivery, not at sampler time. Dropping the receiver cancels every
//! in-flight request. Requests may carry a [`CancelHandle`]
//! ([`Request::with_cancel`]) or a relative deadline
//! ([`Request::with_deadline_s`]): a cancelled or expired request —
//! queued or mid-decode — completes with a typed [`ServeError`] in
//! [`Completion::error`], its pages released through the refcount/CoW
//! path (registered prefix pages stay adoptable) and its slot handed
//! to the same scheduling iteration's admission pass.
//!
//! With `--token-budget` each worker runs the **token-budget iteration
//! scheduler** instead of the phase-segregated loop: every round carries
//! all live decode tokens first, then resumable prefill chunks
//! (`--prefill-chunk`) up to the budget, so one long prompt interleaves
//! with live decodes instead of stalling them; [`ServeReport`] carries
//! the time-to-first-token and time-between-tokens p50/p99 that bound
//! quantifies, plus the per-round composition ([`RoundStats`]).
//!
//! Admission scans a **bounded window** past the queue head
//! (`--admit-window`, default [`ADMIT_SCAN_WINDOW`], 0 = unbounded) so
//! one deferred large request cannot block later requests that still fit
//! the remaining pages, and the window order is a [`SchedPolicy`]: FIFO,
//! or shortest-job-first by prefix-aware worst-case pages
//! (`--sched sjf`). With `--prefix-cache`
//! each worker shares committed prompt pages across requests
//! (admissions alias page-aligned cached prefixes and skip their
//! prefill), and `--swap-pages N` backs eviction with a host swap arena
//! so the pool can oversubscribe; [`ServeReport::reuse`] carries the
//! hit/evict/swap counters and [`ServeReport::kv_swap_bytes`] the swap
//! traffic the imax cost model charged through the DMA transfer mode.
//!
//! The kernel executor comes from the
//! [`BackendRegistry`], so the same loop can serve on native kernels,
//! instrumented-IMAX accounting (per-phase modeled costs in the report),
//! PJRT, or a heterogeneous per-layer-range placement
//! (`--backend "0-11:imax:fpga2,12-23:native"`) — placement coverage is
//! validated against the model's layer count before any worker spawns,
//! and the report keeps one summed sub-report per distinct backend
//! ([`ServeReport::per_backend`]). Reports per-request latency and
//! aggregate throughput, the metrics the paper's E2E evaluation is built
//! on.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::analysis::{self, AuditExec, Finding};
use crate::coordinator::scheduler::{
    AdaptiveBudget, AdmitError, Admitted, ContinuousBatcher, FinishReason, RoundStats,
    SchedPolicy, SessionLog, TenantFairness,
};
pub use crate::coordinator::scheduler::{CancelHandle, Request, TokenEvent};
use crate::imax::timing::RunBreakdown;
use crate::model::drafter::DrafterSpec;
use crate::model::engine::{Engine, DEFAULT_UBATCH};
use crate::model::kv_cache::{KvReuseStats, KvScheme, DEFAULT_PAGE_SIZE};
use crate::model::sampler::Sampler;
use crate::model::weights::ModelWeights;
use crate::runtime::backend::{BackendRegistry, BackendReport, ExecSpec};
use crate::util::stats::{percentile, Summary};

/// Default admission scan depth past a deferred head per round
/// (`ServeOptions::admit_window`). Bounded so a worker never starves
/// decode rounds walking a long queue, but deep enough that one
/// oversized head doesn't idle free pages (the head-of-line fix).
pub const ADMIT_SCAN_WINDOW: usize = 8;

/// What each worker thread hands back when it drains: its backend
/// report, peak resident KV bytes, reuse counters, round stats, and the
/// audit findings its run accumulated (always empty without `--audit`).
type WorkerStats = (BackendReport, usize, KvReuseStats, RoundStats, Vec<Finding>);

/// Serving configuration beyond the request list.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Concurrent sessions per worker engine (continuous-batching width).
    pub slots_per_worker: usize,
    /// Prefill chunk size.
    pub ubatch: usize,
    /// Base seed; request `id` is mixed in so results are independent of
    /// which worker serves a request.
    pub sampler_seed: u64,
    /// Kernel executor, built per worker via the [`BackendRegistry`].
    pub spec: ExecSpec,
    /// KV page size in tokens (`--page-size`).
    pub page_size: usize,
    /// Per-worker KV page budget (`--kv-pages`). `None` fully backs every
    /// slot to `max_seq` (admission then only gates on slots); `Some(n)`
    /// caps resident KV memory and admission gates on free pages, which
    /// is what lets many short sequences share a budget that fixed-stride
    /// slots would exhaust.
    pub kv_pages: Option<usize>,
    /// Share committed prompt-prefix pages across requests on each
    /// worker (`--prefix-cache`): warm admissions alias cached pages and
    /// skip the aliased span's prefill.
    pub prefix_cache: bool,
    /// Host swap-arena capacity in pages per worker (`--swap-pages`;
    /// 0 disables). Evicted cached pages move host-side and swap back in
    /// on demand instead of being dropped. Requires `prefix_cache`.
    pub swap_pages: usize,
    /// Admission order within the scan window (`--sched fifo|sjf`).
    pub sched: SchedPolicy,
    /// Per-round token budget (`--token-budget`). `None` keeps the
    /// phase-segregated loop (whole prefill at admission); `Some(n)`
    /// switches each worker to token-budget iteration scheduling: every
    /// round carries all live decode tokens first, then resumable
    /// prefill chunks up to the budget, so a long prompt never stalls
    /// live decodes.
    pub token_budget: Option<usize>,
    /// Largest resumable prefill chunk one round may carry per request
    /// (`--prefill-chunk`; default = the ubatch size). Only meaningful
    /// with `token_budget` set.
    pub prefill_chunk: Option<usize>,
    /// How many queued requests admission may scan past a deferred head
    /// per round (`--admit-window`; 0 = unbounded).
    pub admit_window: usize,
    /// Speculative decoding draft length (`--speculate`; 0 = vanilla
    /// decode). Each decode round drafts up to this many tokens per
    /// live sequence and verifies them in one batched ubatch — output
    /// stays bit-identical while accepted tokens amortize the per-round
    /// weight stream.
    pub speculate: usize,
    /// Draft proposer (`--drafter ngram[:N]`; default `ngram:3`). Only
    /// meaningful with `speculate > 0`.
    pub drafter: Option<DrafterSpec>,
    /// KV page encoding (`--kv-quant f16|q8_0`; default f16, the
    /// bit-exact reference). `q8_0` quantizes pages on commit and
    /// dequantizes on attention read: ~1.88× less KV residency, swap
    /// traffic, and modeled attention-stream bytes, at the cost of
    /// bounded logit drift (see `rust/tests/kv_quant_accuracy.rs`).
    pub kv_quant: KvScheme,
    /// Run the static analyzers during the serve (`--audit`): every
    /// worker's backend is wrapped in [`AuditExec`] (each forward step's
    /// launch stream runs the plan-time schedule verifier) and the
    /// cross-subsystem invariant auditor runs between decode rounds.
    /// Findings surface in [`ServeReport::audit_findings`]; execution is
    /// bit-identical either way.
    pub audit: bool,
    /// Closed-loop per-round token budget (`--adaptive-budget MIN:MAX`):
    /// each worker steers its round budget inside `[MIN, MAX]` from the
    /// modeled LOAD/EXEC balance of the round it just settled (see
    /// [`AdaptiveBudget`]). Implies token-budget scheduling — the budget
    /// starts at `token_budget` (clamped) when set, else at `MAX`.
    /// Functional backends feed no balance, so the budget stays frozen.
    pub adaptive_budget: Option<AdaptiveBudget>,
    /// Queue-depth-aware prefill chunk sizing (`--adaptive-chunk`): each
    /// round splits its leftover budget evenly across every waiting
    /// prefill cursor (capped by `prefill_chunk`), advancing many
    /// prompts a little per round instead of one prompt a lot. Requires
    /// token-budget scheduling.
    pub adaptive_chunk: bool,
    /// Per-tenant admission weights for [`SchedPolicy::Wfq`]
    /// (`--tenant-weights name:w,...`). Unlisted tenants — and untagged
    /// requests — weigh 1.
    pub tenant_weights: Vec<(String, f64)>,
    /// TTFT target (`--slo-ttft-s`): a served request attains it when
    /// its first delivered token lands within this many seconds of
    /// enqueue. Grades [`ServeReport::slo_attainment`] and the
    /// per-tenant breakdown; `None` disables.
    pub slo_ttft_s: Option<f64>,
    /// Per-request p99 time-between-tokens target (`--slo-tbt-s`);
    /// `None` disables.
    pub slo_tbt_s: Option<f64>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            slots_per_worker: 4,
            ubatch: DEFAULT_UBATCH,
            sampler_seed: 42,
            spec: ExecSpec::Native,
            page_size: DEFAULT_PAGE_SIZE,
            kv_pages: None,
            prefix_cache: false,
            swap_pages: 0,
            sched: SchedPolicy::Fifo,
            token_budget: None,
            prefill_chunk: None,
            admit_window: ADMIT_SCAN_WINDOW,
            speculate: 0,
            drafter: None,
            kv_quant: KvScheme::F16,
            audit: false,
            adaptive_budget: None,
            adaptive_chunk: false,
            tenant_weights: Vec::new(),
            slo_ttft_s: None,
            slo_tbt_s: None,
        }
    }
}

/// Typed reason a request completed without running to its full
/// `n_out` — carried in [`Completion::error`] so consumers can branch
/// on outcome instead of parsing strings.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission rejected the request outright: its worst case exceeds
    /// the page pool or context window, or the cache failed during
    /// prefill.
    Rejected { reason: String },
    /// The defensive stall guard fired: the request would defer forever
    /// on an idle engine. Formerly a worker-killing `assert!` in the
    /// serve loop; now a typed completion surfaced through the report.
    Stalled { reason: String },
    /// Torn down by its [`CancelHandle`] or a dropped stream receiver;
    /// [`Completion::tokens`] keeps what was delivered before teardown.
    Cancelled,
    /// Its [`Request::deadline_s`] expired, in the queue or mid-decode.
    DeadlineExpired,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Rejected { reason } | ServeError::Stalled { reason } => {
                f.write_str(reason)
            }
            ServeError::Cancelled => f.write_str("cancelled before completion"),
            ServeError::DeadlineExpired => f.write_str("deadline expired"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Completed request with timing (epoch-relative marks are seconds since
/// the serve call started).
#[derive(Clone, Debug)]
pub struct Completion {
    /// [`Request::id`] of the originating request.
    pub id: usize,
    /// Tenant class of the originating [`Request`] (`None` = untagged);
    /// keys the per-tenant breakdown in [`ServeReport::tenants`].
    pub tenant: Option<String>,
    /// Every token delivered (teardown keeps the partial stream).
    pub tokens: Vec<u32>,
    /// Time spent in the shared queue before admission.
    pub queue_s: f64,
    /// Prefill processing time attributed to this request.
    pub prefill_s: f64,
    /// Decode processing time attributed to this request.
    pub decode_s: f64,
    /// Enqueue → completion.
    pub total_s: f64,
    /// Index of the worker engine that served the request.
    pub worker: usize,
    /// Epoch-relative admission mark.
    pub admitted_s: f64,
    /// Epoch-relative instant the first decode round ran.
    pub decode_start_s: f64,
    /// Epoch-relative completion (or teardown) mark.
    pub finished_s: f64,
    /// Enqueue → first *delivered* token (queue time included); `None`
    /// for rejected or zero-output requests.
    pub ttft_s: Option<f64>,
    /// Per-request p99 gap between successive delivery events (`None`
    /// below two events).
    pub tbt_p99_s: Option<f64>,
    /// Epoch-relative delivery instant of each sampled token (stamped
    /// when the token reached the consumer stream, not at sampler
    /// time; tokens delivered in one event share an instant).
    pub token_marks_s: Vec<f64>,
    /// Epoch-relative instant of each delivery event (one per sink
    /// call; a speculative verify's accepted run is one event) — the
    /// marks TBT percentiles are measured over.
    pub delivery_marks_s: Vec<f64>,
    /// Speculative decoding: batched verify passes this request ran
    /// (0 with speculation off).
    pub verify_calls: usize,
    /// Drafted tokens proposed across those passes.
    pub draft_tokens: usize,
    /// Drafted tokens accepted across those passes.
    pub draft_accepted: usize,
    /// `Some` when the request did not run to completion: rejected at
    /// admission, stalled, cancelled, or past its deadline. Cancelled
    /// and expired completions keep the tokens delivered before
    /// teardown.
    pub error: Option<ServeError>,
}

impl Completion {
    /// Gaps between successive delivery events (empty below two
    /// events).
    pub fn tbt_gaps_s(&self) -> Vec<f64> {
        self.delivery_marks_s.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// Tokens emitted per verify pass (accepted drafts plus the pass's
    /// own always-emitted token); `None` without any verify pass.
    pub fn accepted_tokens_per_verify(&self) -> Option<f64> {
        if self.verify_calls == 0 {
            None
        } else {
            Some((self.draft_accepted + self.verify_calls) as f64 / self.verify_calls as f64)
        }
    }

    /// Fraction of drafted tokens accepted (`None` when nothing was
    /// drafted).
    pub fn draft_accept_rate(&self) -> Option<f64> {
        if self.draft_tokens == 0 {
            None
        } else {
            Some(self.draft_accepted as f64 / self.draft_tokens as f64)
        }
    }
}

/// Per-tenant slice of a serve run: latency percentiles and SLO
/// attainment over one tenant class's completions (see
/// [`ServeReport::tenants`]). Untagged requests aggregate under the
/// empty tenant name.
#[derive(Clone, Debug)]
pub struct TenantReport {
    /// Tenant name (`""` for untagged requests).
    pub tenant: String,
    /// All completions of this tenant, whatever their outcome.
    pub requests: usize,
    /// Completions that ran to their full `n_out`.
    pub served: usize,
    /// Completions torn down by a [`CancelHandle`].
    pub cancelled: usize,
    /// Completions whose deadline expired.
    pub deadline_expired: usize,
    /// Completions rejected or stalled at admission.
    pub rejected: usize,
    /// Tokens delivered to this tenant (teardown remainders included).
    pub total_tokens: usize,
    /// Median TTFT over this tenant's requests that delivered at least
    /// one token (0 when none did).
    pub ttft_p50_s: f64,
    /// p99 TTFT over the same requests.
    pub ttft_p99_s: f64,
    /// Median gap between successive delivery events of this tenant's
    /// requests (0 below two events).
    pub tbt_p50_s: f64,
    /// p99 delivery gap over the same events.
    pub tbt_p99_s: f64,
    /// Fraction of this tenant's *served* requests meeting every
    /// configured SLO target; `None` when no SLO is set or nothing was
    /// served.
    pub slo_attainment: Option<f64>,
}

/// Whether one completion attains every configured SLO target. A
/// request that delivered no first token yet completed (zero-output
/// requests) vacuously attains TTFT; a request with fewer than two
/// delivery events vacuously attains TBT.
fn attains_slo(c: &Completion, slo_ttft_s: Option<f64>, slo_tbt_s: Option<f64>) -> bool {
    let ttft_ok = match (slo_ttft_s, c.ttft_s) {
        (Some(slo), Some(ttft)) => ttft <= slo,
        _ => true,
    };
    let tbt_ok = match (slo_tbt_s, c.tbt_p99_s) {
        (Some(slo), Some(tbt)) => tbt <= slo,
        _ => true,
    };
    ttft_ok && tbt_ok
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Every request's outcome, in completion order.
    pub completions: Vec<Completion>,
    /// Wall seconds from the serve call to the last completion.
    pub wall_s: f64,
    /// Tokens delivered across all requests.
    pub total_tokens: usize,
    /// Delivered tokens per wall second.
    pub throughput_tok_s: f64,
    /// Median enqueue→completion latency.
    pub latency_p50_s: f64,
    /// p95 enqueue→completion latency.
    pub latency_p95_s: f64,
    /// Mean enqueue→completion latency.
    pub latency_mean_s: f64,
    /// Time-to-first-token percentiles over requests that delivered at
    /// least one token (enqueue → first *delivered* token — delivery
    /// time, not sampler time; cancelled/expired requests that streamed
    /// tokens before teardown contribute honestly).
    pub ttft_p50_s: f64,
    /// p99 of the same delivery-time TTFT distribution.
    pub ttft_p99_s: f64,
    /// Time-between-tokens percentiles over every gap between
    /// successive *delivery events* of every request — the tail-latency
    /// metric accelerator serving stacks are judged on, and what the
    /// token-budget scheduler bounds. A speculative verify delivers its
    /// accepted run as one event, so bursts cannot deflate these with
    /// ~0 intra-burst gaps.
    pub tbt_p50_s: f64,
    /// p99 of the same delivery-time inter-event gaps.
    pub tbt_p99_s: f64,
    /// Requests that completed as [`ServeError::Cancelled`].
    pub cancelled: usize,
    /// Requests that completed as [`ServeError::DeadlineExpired`].
    pub deadline_expired: usize,
    /// Per-tenant latency/SLO breakdown, sorted by tenant name (the
    /// empty name aggregates untagged requests). Empty when no request
    /// carried a tenant tag.
    pub tenants: Vec<TenantReport>,
    /// TTFT target the run was graded against (`--slo-ttft-s`).
    pub slo_ttft_s: Option<f64>,
    /// Per-request p99 TBT target the run was graded against
    /// (`--slo-tbt-s`).
    pub slo_tbt_s: Option<f64>,
    /// Fraction of all *served* requests meeting every configured SLO
    /// target; `None` when no SLO is set or nothing was served.
    pub slo_attainment: Option<f64>,
    /// Round composition merged over workers (how token-budgeted rounds
    /// actually mixed decode tokens with prefill chunks).
    pub rounds: RoundStats,
    /// Which backend served the run.
    pub backend: String,
    /// Modeled IMAX per-phase costs summed over workers (imax backend).
    pub modeled: Option<RunBreakdown>,
    /// Offloaded / total MACs across the run (imax backend).
    pub offload_ratio: Option<f64>,
    /// One summed sub-report per distinct backend when the run was
    /// heterogeneous (placement specs); empty for single-backend runs.
    pub per_backend: Vec<BackendReport>,
    /// Peak resident KV bytes (page-granular, in the pool's page
    /// encoding — see [`ServeReport::kv_scheme`]), summed over each
    /// worker's own peak — an upper bound on simultaneous residency,
    /// and the quantity `--kv-pages` caps per worker.
    pub kv_peak_bytes: usize,
    /// KV page encoding the run used (`"f16"` | `"q8_0"`,
    /// `--kv-quant`) — makes every KV byte figure in this report and in
    /// bench JSON self-describing.
    pub kv_scheme: String,
    /// Prefix-hit / CoW / eviction / swap counters, merged over workers.
    pub reuse: KvReuseStats,
    /// KV swap traffic charged through the imax DMA cost model (bytes
    /// in the pool's page encoding, both directions; 0 for functional
    /// backends, which move no modeled bytes).
    pub kv_swap_bytes: u64,
    /// Speculative decoding aggregates over all served requests: verify
    /// passes run, drafted tokens proposed, drafted tokens accepted
    /// (all 0 with `--speculate 0`).
    pub verify_calls: usize,
    /// Drafted tokens proposed across the run.
    pub draft_tokens: usize,
    /// Drafted tokens accepted across the run.
    pub draft_accepted: usize,
    /// Aggregate tokens emitted per verify pass (accepted drafts plus
    /// each pass's always-emitted token); `None` when no verify ran.
    pub accepted_tokens_per_verify: Option<f64>,
    /// Aggregate fraction of drafted tokens accepted; `None` when
    /// nothing was drafted.
    pub draft_accept_rate: Option<f64>,
    /// Modeled weight/activation bytes streamed to the accelerator,
    /// summed over workers (0 for functional backends).
    pub streamed_bytes: u64,
    /// Modeled bytes streamed per accepted (= emitted) token: the
    /// paper's LOAD-bound decode cost per token of useful work.
    /// Speculation drives this down — each accepted draft token shares
    /// its round's weight stream. `None` for functional backends.
    pub streamed_bytes_per_token: Option<f64>,
    /// Static-analysis findings merged over workers (`--audit`): every
    /// schedule-verifier violation from the [`AuditExec`] wrapper plus
    /// every cross-subsystem auditor violation observed between rounds.
    /// Always empty without `--audit`; empty **with** `--audit`
    /// certifies the run against the full rule catalog in
    /// [`crate::analysis`].
    pub audit_findings: Vec<Finding>,
}

/// Serve a batch of requests over `n_workers` native-kernel workers;
/// blocks until all requests complete. Thin wrapper over [`serve_with`]
/// with default continuous-batching options.
pub fn serve(
    weights: &ModelWeights,
    requests: Vec<Request>,
    n_workers: usize,
    sampler_seed: u64,
) -> ServeReport {
    let opts = ServeOptions {
        sampler_seed,
        ..ServeOptions::default()
    };
    // Invariant: the default options carry the native spec, which has no
    // failure mode in `BackendRegistry::validate`, so with `n_workers
    // >= 1` this convenience wrapper cannot see a validation error.
    serve_with(weights, requests, n_workers, &opts).expect("native backend always builds")
}

/// Serve with explicit options (backend spec, session slots, ubatch).
pub fn serve_with(
    weights: &ModelWeights,
    requests: Vec<Request>,
    n_workers: usize,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let arrivals = requests.into_iter().map(|r| (r, 0.0)).collect();
    serve_inner(weights, arrivals, n_workers, opts, None)
}

/// Serve a *timed* open-loop trace: each request enters the shared
/// admission queue `at_s` wall-clock seconds after the call (a feeder
/// thread holds it back until then), so queue time, deadlines and SLO
/// grading measure real load instead of an all-at-once batch. This is
/// the entry behind `serve --scenario` — pair it with
/// [`crate::harness::scenario::Scenario::arrivals`]. Requests with
/// non-positive `at_s` enqueue immediately; passing all zeros is
/// exactly [`serve_with`].
pub fn serve_trace(
    weights: &ModelWeights,
    arrivals: Vec<(Request, f64)>,
    n_workers: usize,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    serve_inner(weights, arrivals, n_workers, opts, None)
}

/// [`serve_trace`] with live per-token delivery (see
/// [`serve_streaming`]): returns immediately; the feeder thread
/// releases requests at their arrival times while the receiver streams
/// every delivered token.
pub fn serve_trace_streaming(
    weights: &ModelWeights,
    arrivals: Vec<(Request, f64)>,
    n_workers: usize,
    opts: &ServeOptions,
) -> Result<StreamingServe> {
    validate_opts(weights, n_workers, opts)?;
    let (event_tx, events) = mpsc::channel::<TokenEvent>();
    let weights = weights.clone();
    let opts = opts.clone();
    let handle = thread::spawn(move || {
        serve_inner(&weights, arrivals, n_workers, &opts, Some(event_tx))
    });
    Ok(StreamingServe { events, handle })
}

/// A streaming serve run: the live token stream plus the handle that
/// yields the final [`ServeReport`] once the run drains.
pub struct StreamingServe {
    /// Live multiplexed token stream — one [`TokenEvent`] per delivered
    /// token of every request, in delivery order. Dropping this
    /// receiver cancels every in-flight and queued request.
    pub events: mpsc::Receiver<TokenEvent>,
    handle: thread::JoinHandle<Result<ServeReport>>,
}

impl StreamingServe {
    /// Block until the run drains and return the final report. A panic
    /// on the serve thread surfaces as a typed error, not a re-panic on
    /// the caller's thread.
    pub fn join(self) -> Result<ServeReport> {
        match self.handle.join() {
            Ok(report) => report,
            Err(_) => Err(anyhow::anyhow!("serve thread panicked before producing a report")),
        }
    }

    /// Split into the event stream and the report handle — e.g. to
    /// drop the receiver (cancelling all requests) while still joining
    /// for the report.
    #[allow(clippy::type_complexity)]
    pub fn into_parts(
        self,
    ) -> (mpsc::Receiver<TokenEvent>, thread::JoinHandle<Result<ServeReport>>) {
        (self.events, self.handle)
    }
}

/// Serve with incremental per-token delivery: returns immediately with
/// a [`StreamingServe`] whose `events` receiver yields every delivered
/// token live (TTFT/TBT marks are stamped at exactly these deliveries).
/// Dropping the receiver mid-run cancels all outstanding requests —
/// their pages are released mid-decode and each completes with
/// [`ServeError::Cancelled`] in the final report.
pub fn serve_streaming(
    weights: &ModelWeights,
    requests: Vec<Request>,
    n_workers: usize,
    opts: &ServeOptions,
) -> Result<StreamingServe> {
    // Fail fast on the caller's thread; the spawned run re-validates
    // cheaply.
    validate_opts(weights, n_workers, opts)?;
    let (event_tx, events) = mpsc::channel::<TokenEvent>();
    let weights = weights.clone();
    let opts = opts.clone();
    let handle = thread::spawn(move || {
        let arrivals = requests.into_iter().map(|r| (r, 0.0)).collect();
        serve_inner(&weights, arrivals, n_workers, &opts, Some(event_tx))
    });
    Ok(StreamingServe { events, handle })
}

fn validate_opts(weights: &ModelWeights, n_workers: usize, opts: &ServeOptions) -> Result<()> {
    if n_workers == 0 {
        anyhow::bail!("n_workers must be at least 1");
    }
    if opts.slots_per_worker == 0 {
        anyhow::bail!("slots_per_worker must be at least 1");
    }
    if opts.ubatch == 0 {
        anyhow::bail!("ubatch must be at least 1");
    }
    if opts.page_size == 0 {
        anyhow::bail!("page_size must be at least 1");
    }
    if opts.kv_pages == Some(0) {
        anyhow::bail!("kv_pages must be at least 1");
    }
    if opts.token_budget == Some(0) {
        anyhow::bail!("token_budget must be at least 1");
    }
    if opts.prefill_chunk == Some(0) {
        anyhow::bail!("prefill_chunk must be at least 1");
    }
    if opts.prefill_chunk.is_some() && opts.token_budget.is_none() && opts.adaptive_budget.is_none()
    {
        anyhow::bail!(
            "prefill_chunk only applies to the token-budget scheduler \
             (pass --token-budget or --adaptive-budget)"
        );
    }
    if opts.adaptive_chunk && opts.token_budget.is_none() && opts.adaptive_budget.is_none() {
        anyhow::bail!(
            "adaptive_chunk only applies to the token-budget scheduler \
             (pass --token-budget or --adaptive-budget)"
        );
    }
    for (slo, name) in [(opts.slo_ttft_s, "slo_ttft_s"), (opts.slo_tbt_s, "slo_tbt_s")] {
        if let Some(v) = slo {
            if !v.is_finite() || v <= 0.0 {
                anyhow::bail!("{name} must be a positive number of seconds, got {v}");
            }
        }
    }
    for (name, w) in &opts.tenant_weights {
        if name.is_empty() {
            anyhow::bail!("tenant_weights entries need a non-empty tenant name");
        }
        if !w.is_finite() || *w <= 0.0 {
            anyhow::bail!("tenant {name:?}: admission weight must be positive, got {w}");
        }
    }
    if opts.swap_pages > 0 && !opts.prefix_cache {
        anyhow::bail!(
            "swap_pages requires prefix_cache: only indexed prefix pages are ever \
             evicted to the host arena (pass --prefix-cache)"
        );
    }
    if opts.drafter.is_some() && opts.speculate == 0 {
        anyhow::bail!(
            "drafter only applies to speculative decoding (pass --speculate k)"
        );
    }
    if opts.kv_quant == KvScheme::Q8_0
        && weights.cfg.kv_dim() % crate::quant::q8_0::QK8_0 != 0
    {
        // Fail fast on the caller's thread instead of panicking inside a
        // worker's pool construction.
        anyhow::bail!(
            "--kv-quant q8_0 needs kv_dim divisible by {} (model has kv_dim {})",
            crate::quant::q8_0::QK8_0,
            weights.cfg.kv_dim()
        );
    }
    BackendRegistry::validate(&opts.spec)?;
    if let ExecSpec::Placement(p) = &opts.spec {
        // Fail fast on a placement that leaves layers of *this* model
        // uncovered — better than a routing panic on a worker thread.
        p.validate_layers(weights.cfg.n_layers)?;
    }
    Ok(())
}

/// Lock the shared admission queue, recovering from poisoning: every
/// mutation under the lock is a single drain or push of plain request
/// data, so a worker that panicked while holding the guard cannot have
/// left the queue half-mutated — the surviving workers keep draining it
/// rather than cascading the panic.
fn lock_queue(
    queue: &Mutex<VecDeque<(Request, Instant)>>,
) -> std::sync::MutexGuard<'_, VecDeque<(Request, Instant)>> {
    queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The serving loop behind [`serve_with`], [`serve_trace`] and the
/// streaming variants: worker threads over a shared queue, each reaping
/// cancelled/expired flights before every admission pass and delivering
/// tokens into `events` (when streaming) the moment the scheduler emits
/// them. Requests whose arrival offset is positive are held back by a
/// feeder thread and pushed at their wall-clock arrival instant.
fn serve_inner(
    weights: &ModelWeights,
    arrivals: Vec<(Request, f64)>,
    n_workers: usize,
    opts: &ServeOptions,
    events: Option<mpsc::Sender<TokenEvent>>,
) -> Result<ServeReport> {
    validate_opts(weights, n_workers, opts)?;
    let n_req = arrivals.len();
    let started = Instant::now();

    // Shared admission queue with enqueue timestamps. An all-immediate
    // trace (every offset <= 0, the `serve_with` path) enqueues up
    // front; a timed trace starts empty and a feeder thread pushes each
    // request at its arrival instant, so queue time and deadlines are
    // measured from the *arrival*, not from the call.
    let timed = arrivals.iter().any(|(_, at_s)| *at_s > 0.0);
    let queue: Arc<Mutex<VecDeque<(Request, Instant)>>> =
        Arc::new(Mutex::new(VecDeque::new()));
    let feeding_done = Arc::new(AtomicBool::new(!timed));
    let mut feeder: Option<thread::JoinHandle<()>> = None;
    if timed {
        let mut arrivals = arrivals;
        // The feeder walks the trace in arrival order regardless of how
        // the caller sorted it (ties keep caller order).
        arrivals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        let queue = Arc::clone(&queue);
        let feeding_done = Arc::clone(&feeding_done);
        feeder = Some(thread::spawn(move || {
            for (req, at_s) in arrivals {
                let target = Duration::from_secs_f64(at_s.max(0.0));
                loop {
                    let elapsed = started.elapsed();
                    if elapsed >= target {
                        break;
                    }
                    // Bounded naps so a long trace stays responsive to
                    // process teardown without busy-waiting.
                    thread::sleep((target - elapsed).min(Duration::from_millis(5)));
                }
                lock_queue(&queue).push_back((req, Instant::now()));
            }
            feeding_done.store(true, Ordering::Release);
        }));
    } else {
        *lock_queue(&queue) =
            arrivals.into_iter().map(|(r, _)| (r, Instant::now())).collect();
    }
    let (tx, rx) = mpsc::channel::<Completion>();

    let mut handles = Vec::new();
    for worker in 0..n_workers {
        let queue = Arc::clone(&queue);
        let feeding_done = Arc::clone(&feeding_done);
        let tx = tx.clone();
        let weights = weights.clone();
        let opts = opts.clone();
        let events = events.clone();
        handles.push(thread::spawn(move || -> WorkerStats {
            // Invariant: `validate_opts` ran `BackendRegistry::validate`
            // on this exact spec before any worker spawned, and `build`
            // has no failure mode a passing `validate` does not share.
            let backend =
                BackendRegistry::build(&opts.spec).expect("spec validated before spawn");
            // One code path for both modes: disabled, the wrapper is a
            // pure passthrough; enabled, every completed step's launch
            // stream runs the plan-time schedule verifier.
            let mut exec = AuditExec::new(backend, opts.audit);
            let mut audit_findings: Vec<Finding> = Vec::new();
            let mut engine = Engine::with_paged_slots_kv(
                weights,
                opts.slots_per_worker,
                opts.page_size,
                opts.kv_pages,
                opts.kv_quant,
            );
            if opts.prefix_cache {
                engine.enable_prefix_cache();
                if opts.swap_pages > 0 {
                    engine.set_kv_swap_capacity(opts.swap_pages);
                }
            }
            let mut batcher = ContinuousBatcher::new(engine, opts.ubatch, started);
            if let Some(budget) = opts.token_budget {
                batcher = batcher.with_token_budget(budget);
            }
            if let Some(spec) = opts.adaptive_budget {
                batcher = batcher.with_adaptive_budget(spec);
            }
            if let Some(chunk) = opts.prefill_chunk {
                // validate_opts guarantees a budget (fixed or adaptive)
                // accompanies the chunk bound.
                batcher = batcher.with_prefill_chunk(chunk);
            }
            if opts.adaptive_chunk {
                batcher = batcher.with_adaptive_chunk(true);
            }
            // WFQ ledger: admitted work charges each tenant's weighted
            // account; `--sched wfq` orders every admission window by
            // least-served tenant. Per worker, like the engine itself.
            let mut fairness = TenantFairness::new(&opts.tenant_weights);
            if opts.speculate > 0 {
                batcher =
                    batcher.with_speculation(opts.speculate, opts.drafter.unwrap_or_default());
            }
            if let Some(event_tx) = events {
                // Streaming delivery: push every token the instant the
                // scheduler emits it. A failed send means the consumer
                // dropped the receiver — the batcher latches
                // delivery-closed and the loop below cancels the run.
                batcher = batcher
                    .with_delivery(Box::new(move |ev: TokenEvent| event_tx.send(ev).is_ok()));
            }
            let send = |log: SessionLog, tx: &mpsc::Sender<Completion>| {
                let ttft_s = log.ttft_s();
                let gaps = log.tbt_gaps_s();
                let tbt_p99_s =
                    if gaps.is_empty() { None } else { Some(percentile(&gaps, 99.0)) };
                let error = match log.reason {
                    FinishReason::Completed => None,
                    FinishReason::Cancelled => Some(ServeError::Cancelled),
                    FinishReason::DeadlineExpired => Some(ServeError::DeadlineExpired),
                };
                tx.send(Completion {
                    id: log.id,
                    tenant: log.tenant,
                    total_s: log.queue_s + (log.finished_s - log.admitted_s),
                    tokens: log.tokens,
                    queue_s: log.queue_s,
                    prefill_s: log.prefill_s,
                    decode_s: log.decode_s,
                    worker,
                    admitted_s: log.admitted_s,
                    decode_start_s: log.decode_start_s,
                    finished_s: log.finished_s,
                    ttft_s,
                    tbt_p99_s,
                    token_marks_s: log.token_marks_s,
                    delivery_marks_s: log.delivery_marks_s,
                    verify_calls: log.verify_calls,
                    draft_tokens: log.draft_tokens,
                    draft_accepted: log.draft_accepted,
                    error,
                })
                .ok();
            };
            // A request that never reached a slot (rejected, stalled,
            // cancelled or expired while queued) still completes — with
            // a typed error and zero tokens.
            let send_error = |id: usize,
                              tenant: Option<String>,
                              queue_s: f64,
                              error: ServeError,
                              tx: &mpsc::Sender<Completion>| {
                let now = started.elapsed().as_secs_f64();
                tx.send(Completion {
                    id,
                    tenant,
                    tokens: Vec::new(),
                    queue_s,
                    prefill_s: 0.0,
                    decode_s: 0.0,
                    total_s: queue_s,
                    worker,
                    admitted_s: now,
                    decode_start_s: now,
                    finished_s: now,
                    ttft_s: None,
                    tbt_p99_s: None,
                    token_marks_s: Vec::new(),
                    delivery_marks_s: Vec::new(),
                    verify_calls: 0,
                    draft_tokens: 0,
                    draft_accepted: 0,
                    error: Some(error),
                })
                .ok();
            };
            loop {
                // Cancellation/deadline sweep *before* admission: a
                // reaped flight's slot and pages are available to the
                // admission pass right below, and the token budget it
                // would have consumed is spent by this iteration's
                // round — same-round reflow.
                for log in batcher.reap() {
                    send(log, &tx);
                }
                if batcher.delivery_closed() {
                    // The stream consumer is gone: nothing further can
                    // be delivered. Cancel the backlog; live flights
                    // were reaped above (delivery-closed cancels all).
                    // With a feeder still releasing a timed trace, keep
                    // draining until it finishes so every request still
                    // completes (with a typed error).
                    let backlog: Vec<(Request, Instant)> =
                        lock_queue(&queue).drain(..).collect();
                    for (req, enq) in backlog {
                        send_error(
                            req.id,
                            req.tenant,
                            enq.elapsed().as_secs_f64(),
                            ServeError::Cancelled,
                            &tx,
                        );
                    }
                    if batcher.n_active() == 0 {
                        if feeding_done.load(Ordering::Acquire)
                            && lock_queue(&queue).is_empty()
                        {
                            break;
                        }
                        thread::sleep(Duration::from_micros(200));
                    }
                    continue;
                }
                // Admit new requests *between* decode rounds — the
                // continuous-batching step. The batcher gates on both
                // free session slots and the KV page budget; admission
                // scans a bounded window past the head, so one deferred
                // large request does not block later requests that fit
                // the remaining pages. Everything not admitted returns
                // to the queue front in arrival order.
                loop {
                    if batcher.capacity() == 0 {
                        break;
                    }
                    let window: Vec<(Request, Instant)> = {
                        let mut q = lock_queue(&queue);
                        let take = if opts.admit_window == 0 {
                            q.len()
                        } else {
                            q.len().min(opts.admit_window)
                        };
                        q.drain(..take).collect()
                    };
                    if window.is_empty() {
                        break;
                    }
                    let mut order: Vec<usize> = (0..window.len()).collect();
                    match opts.sched {
                        SchedPolicy::Fifo => {}
                        SchedPolicy::Sjf => {
                            // Shortest job first by prefix-aware effective
                            // cost; stable, so ties keep arrival order.
                            order
                                .sort_by_key(|&i| batcher.effective_cost_pages(&window[i].0));
                        }
                        SchedPolicy::Wfq => {
                            // Least weighted service first: the tenant
                            // furthest behind its fair share goes to the
                            // head of the window; ties keep arrival order.
                            let tenants: Vec<Option<&str>> =
                                window.iter().map(|(r, _)| r.tenant.as_deref()).collect();
                            order = fairness.order(&tenants);
                        }
                    }
                    let mut kept: Vec<Option<(Request, Instant)>> =
                        window.into_iter().map(Some).collect();
                    let mut admitted_any = false;
                    for idx in order {
                        if batcher.capacity() == 0 {
                            break;
                        }
                        // Invariant: `order` is a permutation of
                        // `0..kept.len()`, so each index is taken at
                        // most once and the slot is still `Some` here.
                        let (req, enq) = kept[idx].take().expect("each index visited once");
                        let queue_s = enq.elapsed().as_secs_f64();
                        // Queue-side teardown: a request cancelled or
                        // already past its deadline never takes a slot.
                        if req.is_cancelled() {
                            admitted_any = true;
                            send_error(req.id, req.tenant, queue_s, ServeError::Cancelled, &tx);
                            continue;
                        }
                        if req.deadline_s.map_or(false, |d| queue_s >= d) {
                            admitted_any = true;
                            send_error(
                                req.id,
                                req.tenant,
                                queue_s,
                                ServeError::DeadlineExpired,
                                &tx,
                            );
                            continue;
                        }
                        let sampler =
                            Sampler::top_k(0.9, 40, opts.sampler_seed.wrapping_add(req.id as u64));
                        // Captured before `admit` consumes the request:
                        // the WFQ ledger charges admitted work and the
                        // rejection path tags its completion.
                        let tenant = req.tenant.clone();
                        let work = req.prompt.len() + req.n_out;
                        match batcher.admit(req, sampler, queue_s, &mut exec) {
                            Ok(Admitted::Active) => {
                                admitted_any = true;
                                fairness.charge(tenant.as_deref(), work);
                            }
                            Ok(Admitted::Finished(log)) => {
                                admitted_any = true;
                                fairness.charge(tenant.as_deref(), work);
                                send(log, &tx);
                            }
                            Ok(Admitted::Deferred(req)) => kept[idx] = Some((req, enq)),
                            Err(e) => {
                                // Unservable on this engine (worst case
                                // above the whole pool, or deferred with
                                // nothing active to free pages): complete
                                // it with a typed error instead of
                                // wedging the queue or killing the
                                // worker — formerly an `assert!` here.
                                admitted_any = true;
                                let error = match &e {
                                    AdmitError::Stalled { .. } => {
                                        ServeError::Stalled { reason: e.to_string() }
                                    }
                                    _ => ServeError::Rejected { reason: e.to_string() },
                                };
                                send_error(e.id(), tenant, queue_s, error, &tx);
                            }
                        }
                    }
                    {
                        let mut q = lock_queue(&queue);
                        for item in kept.into_iter().flatten().rev() {
                            q.push_front(item);
                        }
                    }
                    if !admitted_any {
                        // Whole window deferred: pages are pinned by
                        // live flights, so decode below frees them. A
                        // deferral on an *idle* engine can never resolve
                        // and admit reports it as `AdmitError::Stalled`
                        // (handled above) rather than returning Deferred.
                        break;
                    }
                }
                if batcher.n_active() == 0 {
                    if lock_queue(&queue).is_empty() {
                        if feeding_done.load(Ordering::Acquire) {
                            break;
                        }
                        // Timed trace still feeding: idle until the next
                        // arrival lands rather than spinning on the lock.
                        thread::sleep(Duration::from_micros(200));
                    }
                    continue;
                }
                // One interleaved decode step for every live request.
                for log in batcher.decode_round(&mut exec) {
                    send(log, &tx);
                }
                if opts.audit {
                    // Between-round invariant audit: the page pool and
                    // the batcher's budget view must agree at every
                    // round boundary — exactly when admission, teardown,
                    // swap, and speculative rollback have all settled.
                    audit_findings.extend(analysis::audit(batcher.engine(), &batcher));
                }
            }
            if opts.audit {
                // Final audit over the drained engine: every flight has
                // retired, so leaks and stale commitments show here.
                audit_findings.extend(analysis::audit(batcher.engine(), &batcher));
            }
            // Peak page-granular KV residency on this worker's engine —
            // the quantity `--kv-pages` budgets.
            let kv_peak = batcher.engine().cache.peak_resident_bytes();
            let reuse = batcher.reuse_stats();
            let rounds = batcher.round_stats();
            audit_findings.extend(exec.take_findings());
            (exec.into_inner().report(), kv_peak, reuse, rounds, audit_findings)
        }));
    }
    drop(tx);

    let mut completions: Vec<Completion> = rx.iter().collect();
    let mut reports = Vec::new();
    let mut kv_peak_total = 0usize;
    let mut reuse = KvReuseStats::default();
    let mut rounds = RoundStats::default();
    let mut audit_findings: Vec<Finding> = Vec::new();
    for h in handles {
        // A worker panic is a serve failure, not a caller panic: surface
        // it as a typed error so the report path stays total.
        let (report, kv_peak, worker_reuse, worker_rounds, worker_findings) = h
            .join()
            .map_err(|_| anyhow::anyhow!("serve worker thread panicked"))?;
        reports.push(report);
        kv_peak_total += kv_peak;
        reuse.merge(&worker_reuse);
        rounds.merge(&worker_rounds);
        audit_findings.extend(worker_findings);
    }
    // Workers only exit once feeding finished, so this join is instant.
    if let Some(f) = feeder {
        f.join().ok();
    }
    completions.sort_by_key(|c| c.id);
    if completions.len() != n_req {
        // Every admission outcome — served, rejected, stalled,
        // cancelled, expired — sends exactly one completion; a mismatch
        // means a request was silently dropped.
        anyhow::bail!(
            "serve drained with {} of {n_req} requests completed",
            completions.len()
        );
    }

    let wall_s = started.elapsed().as_secs_f64();
    let total_tokens: usize = completions.iter().map(|c| c.tokens.len()).sum();
    // Latency statistics cover *served* requests only: a rejection
    // completes in ~0 s and would deflate the percentiles.
    let lats: Vec<f64> = completions
        .iter()
        .filter(|c| c.error.is_none())
        .map(|c| c.total_s)
        .collect();
    let summary = Summary::from_slice(&lats);
    // TTFT and time-between-tokens over every request that delivered at
    // least one token — cancelled and deadline-expired requests did real
    // delivery-time work before teardown; a rejection emits no tokens
    // and contributes to neither.
    let ttfts: Vec<f64> = completions.iter().filter_map(|c| c.ttft_s).collect();
    let gaps: Vec<f64> = completions.iter().flat_map(|c| c.tbt_gaps_s()).collect();
    let cancelled = completions
        .iter()
        .filter(|c| matches!(c.error, Some(ServeError::Cancelled)))
        .count();
    let deadline_expired = completions
        .iter()
        .filter(|c| matches!(c.error, Some(ServeError::DeadlineExpired)))
        .count();
    let merged = BackendReport::merged(&reports);
    let pctl = |p: f64| if lats.is_empty() { 0.0 } else { percentile(&lats, p) };
    let pctl_of = |xs: &[f64], p: f64| if xs.is_empty() { 0.0 } else { percentile(xs, p) };
    let verify_calls: usize = completions.iter().map(|c| c.verify_calls).sum();
    let draft_tokens: usize = completions.iter().map(|c| c.draft_tokens).sum();
    let draft_accepted: usize = completions.iter().map(|c| c.draft_accepted).sum();
    let accepted_tokens_per_verify = if verify_calls == 0 {
        None
    } else {
        Some((draft_accepted + verify_calls) as f64 / verify_calls as f64)
    };
    let draft_accept_rate = if draft_tokens == 0 {
        None
    } else {
        Some(draft_accepted as f64 / draft_tokens as f64)
    };
    let streamed_bytes_per_token = if merged.streamed_bytes == 0 || total_tokens == 0 {
        None
    } else {
        Some(merged.streamed_bytes as f64 / total_tokens as f64)
    };
    // SLO grading covers served requests only — a rejection never ran,
    // so it can neither attain nor miss a latency target. `None` when no
    // target is configured or nothing in the group was served.
    let slo_grade = |cs: &[&Completion]| -> Option<f64> {
        if opts.slo_ttft_s.is_none() && opts.slo_tbt_s.is_none() {
            return None;
        }
        let served: Vec<&Completion> =
            cs.iter().copied().filter(|c| c.error.is_none()).collect();
        if served.is_empty() {
            return None;
        }
        let ok = served
            .iter()
            .filter(|c| attains_slo(c, opts.slo_ttft_s, opts.slo_tbt_s))
            .count();
        Some(ok as f64 / served.len() as f64)
    };
    let all: Vec<&Completion> = completions.iter().collect();
    let slo_attainment = slo_grade(&all);
    // Per-tenant breakdown only when at least one request carried a tag:
    // an untagged run keeps its report shape unchanged.
    let mut by_tenant: BTreeMap<String, Vec<&Completion>> = BTreeMap::new();
    if completions.iter().any(|c| c.tenant.is_some()) {
        for c in &completions {
            by_tenant.entry(c.tenant.clone().unwrap_or_default()).or_default().push(c);
        }
    }
    let tenants: Vec<TenantReport> = by_tenant
        .iter()
        .map(|(name, cs)| {
            let t_ttfts: Vec<f64> = cs.iter().filter_map(|c| c.ttft_s).collect();
            let t_gaps: Vec<f64> = cs.iter().flat_map(|c| c.tbt_gaps_s()).collect();
            TenantReport {
                tenant: name.clone(),
                requests: cs.len(),
                served: cs.iter().filter(|c| c.error.is_none()).count(),
                cancelled: cs
                    .iter()
                    .filter(|c| matches!(c.error, Some(ServeError::Cancelled)))
                    .count(),
                deadline_expired: cs
                    .iter()
                    .filter(|c| matches!(c.error, Some(ServeError::DeadlineExpired)))
                    .count(),
                rejected: cs
                    .iter()
                    .filter(|c| {
                        matches!(
                            c.error,
                            Some(ServeError::Rejected { .. }) | Some(ServeError::Stalled { .. })
                        )
                    })
                    .count(),
                total_tokens: cs.iter().map(|c| c.tokens.len()).sum(),
                ttft_p50_s: pctl_of(&t_ttfts, 50.0),
                ttft_p99_s: pctl_of(&t_ttfts, 99.0),
                tbt_p50_s: pctl_of(&t_gaps, 50.0),
                tbt_p99_s: pctl_of(&t_gaps, 99.0),
                slo_attainment: slo_grade(cs),
            }
        })
        .collect();
    Ok(ServeReport {
        throughput_tok_s: total_tokens as f64 / wall_s,
        latency_p50_s: pctl(50.0),
        latency_p95_s: pctl(95.0),
        latency_mean_s: if lats.is_empty() { 0.0 } else { summary.mean() },
        ttft_p50_s: pctl_of(&ttfts, 50.0),
        ttft_p99_s: pctl_of(&ttfts, 99.0),
        tbt_p50_s: pctl_of(&gaps, 50.0),
        tbt_p99_s: pctl_of(&gaps, 99.0),
        cancelled,
        deadline_expired,
        tenants,
        slo_ttft_s: opts.slo_ttft_s,
        slo_tbt_s: opts.slo_tbt_s,
        slo_attainment,
        rounds,
        completions,
        wall_s,
        total_tokens,
        backend: opts.spec.name(),
        modeled: merged.modeled,
        offload_ratio: merged.offload_ratio,
        kv_swap_bytes: merged.kv_swap_bytes,
        streamed_bytes: merged.streamed_bytes,
        streamed_bytes_per_token,
        per_backend: merged.parts,
        kv_peak_bytes: kv_peak_total,
        kv_scheme: opts.kv_quant.name().to_string(),
        reuse,
        audit_findings,
        verify_calls,
        draft_tokens,
        draft_accepted,
        accepted_tokens_per_verify,
        draft_accept_rate,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::runtime::backend::ImaxSpec;

    fn tiny_weights() -> ModelWeights {
        ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 11)
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|id| Request::new(id, vec![1 + id as u32, 2, 3, 4], 3))
            .collect()
    }

    #[test]
    fn serves_all_requests_single_worker() {
        let rep = serve(&tiny_weights(), reqs(4), 1, 42);
        assert_eq!(rep.completions.len(), 4);
        assert_eq!(rep.total_tokens, 12);
        assert!(rep.throughput_tok_s > 0.0);
        assert_eq!(rep.backend, "native");
        assert!(rep.modeled.is_none());
        for c in &rep.completions {
            assert_eq!(c.tokens.len(), 3);
            assert!(c.prefill_s > 0.0 && c.decode_s > 0.0);
            assert!(c.finished_s >= c.decode_start_s);
        }
    }

    #[test]
    fn multi_worker_completes_and_uses_workers() {
        let rep = serve(&tiny_weights(), reqs(6), 2, 42);
        assert_eq!(rep.completions.len(), 6);
        let workers: std::collections::HashSet<usize> =
            rep.completions.iter().map(|c| c.worker).collect();
        assert!(!workers.is_empty() && workers.len() <= 2);
    }

    #[test]
    fn completions_sorted_by_id() {
        let rep = serve(&tiny_weights(), reqs(5), 2, 7);
        let ids: Vec<usize> = rep.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let rep = serve(&tiny_weights(), reqs(8), 2, 9);
        assert!(rep.latency_p50_s <= rep.latency_p95_s);
        assert!(rep.latency_mean_s > 0.0);
    }

    #[test]
    fn continuous_batching_overlaps_requests() {
        // 8 requests, 2 workers × 2 slots: requests 5..8 are admitted
        // mid-run and must start decoding before the earlier requests on
        // their worker finish. Distinct n_out per request staggers the
        // finishes, so every mid-run admission lands next to a still-live
        // session.
        let requests: Vec<Request> = (0..8)
            .map(|id| Request::new(id, vec![1 + id as u32, 2, 3, 4], 4 + id * 2))
            .collect();
        let opts = ServeOptions {
            slots_per_worker: 2,
            ..ServeOptions::default()
        };
        let rep = serve_with(&tiny_weights(), requests, 2, &opts).unwrap();
        assert_eq!(rep.completions.len(), 8);
        let overlap = rep.completions.iter().any(|late| {
            rep.completions.iter().any(|early| {
                early.worker == late.worker
                    && late.admitted_s > early.decode_start_s
                    && late.decode_start_s < early.finished_s
            })
        });
        assert!(
            overlap,
            "a mid-run admission must decode while an earlier request is still live"
        );
    }

    #[test]
    fn page_budget_serving_completes_under_tight_pool() {
        // 1 worker × 4 slots over 6 pages of 4 tokens = 24 cached tokens:
        // each request's worst case is 4 + 3 − 1 = 6 tokens (2 pages), so
        // at most 3 run concurrently and the rest defer — but everything
        // completes, identically to an unconstrained run.
        let w = tiny_weights();
        let opts = ServeOptions {
            slots_per_worker: 4,
            page_size: 4,
            kv_pages: Some(6),
            ..ServeOptions::default()
        };
        let rep = serve_with(&w, reqs(6), 1, &opts).unwrap();
        assert_eq!(rep.completions.len(), 6);
        for c in &rep.completions {
            assert!(c.error.is_none());
            assert_eq!(c.tokens.len(), 3);
        }
        // Page-granular peak residency is reported and stays inside the
        // configured 6-page budget.
        let cfg = ModelConfig::tiny();
        let pool_bytes = 2 * 6 * cfg.n_layers * 4 * cfg.kv_dim() * 2;
        assert_eq!(rep.kv_scheme, "f16", "default pool encoding");
        assert!(rep.kv_peak_bytes > 0, "peak residency reported");
        assert!(
            rep.kv_peak_bytes <= pool_bytes,
            "{} exceeds the {pool_bytes}-byte budget",
            rep.kv_peak_bytes
        );
        // Same tokens as a run with a fully backed cache.
        let free = serve(&w, reqs(6), 1, 42);
        for (a, b) in rep.completions.iter().zip(&free.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "page budget must not change tokens");
        }
    }

    #[test]
    fn kv_quant_serve_completes_and_reports_scheme() {
        // tiny has kv_dim 128 (32-aligned), so q8_0 pools build. The
        // quantized run must serve every request to completion and
        // report a page-granular peak ~1.88× below the f16 run's on the
        // same workload (exact block math: 34/64 bytes per element
        // pair). Token equality is NOT asserted — q8_0 deliberately
        // breaks bit-identity; `rust/tests/kv_quant_accuracy.rs` bounds
        // the drift instead.
        let w = tiny_weights();
        let f16 = serve(&w, reqs(4), 1, 42);
        let opts = ServeOptions {
            kv_quant: KvScheme::Q8_0,
            ..ServeOptions::default()
        };
        let q8 = serve_with(&w, reqs(4), 1, &opts).unwrap();
        assert_eq!(q8.completions.len(), 4);
        for c in &q8.completions {
            assert!(c.error.is_none());
            assert_eq!(c.tokens.len(), 3);
        }
        assert_eq!(q8.kv_scheme, "q8_0");
        assert_eq!(f16.kv_scheme, "f16");
        assert!(q8.kv_peak_bytes > 0);
        let ratio = f16.kv_peak_bytes as f64 / q8.kv_peak_bytes as f64;
        assert!(
            (ratio - 64.0 / 34.0).abs() < 1e-9,
            "same page-granular peak, compressed encoding: ratio {ratio}"
        );
    }

    #[test]
    fn kv_quant_rejects_unaligned_kv_dim() {
        // 8-dim KV heads cannot form q8_0 blocks (QK8_0 = 32); the
        // option must fail fast at validation, not panic in a worker.
        let cfg = ModelConfig {
            name: "kv-unaligned",
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 8,
            d_ffn: 32,
            vocab_size: 16,
            qk_norm: false,
            rope_theta: 1e4,
            rms_eps: 1e-6,
            max_seq_len: 32,
        };
        let w = ModelWeights::random(&cfg, QuantScheme::Q8_0, 5);
        let opts = ServeOptions {
            kv_quant: KvScheme::Q8_0,
            ..ServeOptions::default()
        };
        let err = serve_with(&w, reqs(1), 1, &opts).unwrap_err();
        assert!(err.to_string().contains("divisible"), "{err}");
    }

    #[test]
    fn oversized_request_completes_with_error() {
        let opts = ServeOptions {
            slots_per_worker: 2,
            page_size: 4,
            kv_pages: Some(4), // 16 cached tokens per worker
            ..ServeOptions::default()
        };
        let mut requests = reqs(3);
        requests.push(Request::new(3, vec![1; 10], 20));
        let rep = serve_with(&tiny_weights(), requests, 1, &opts).unwrap();
        assert_eq!(rep.completions.len(), 4, "rejected request still completes");
        let big = rep.completions.iter().find(|c| c.id == 3).unwrap();
        assert!(big.tokens.is_empty());
        let err = big.error.as_ref().expect("rejected with an error");
        assert!(matches!(err, ServeError::Rejected { .. }), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("never be admitted"), "{msg}");
        for c in rep.completions.iter().filter(|c| c.id != 3) {
            assert!(c.error.is_none(), "small requests are unaffected");
            assert_eq!(c.tokens.len(), 3);
        }
    }

    #[test]
    fn deferred_head_does_not_block_fitting_requests() {
        // Head-of-line fix, parameterized over the admission scan window:
        // pool of 4 pages × 4 tokens per worker. The queue is [medium
        // (3 pages), big (4 pages), small (1 page)]: medium admits, big
        // defers — and small, which fits next to medium, must be admitted
        // *past* the deferred big whenever the window reaches it
        // (explicit depth ≥ 2 or 0 = unbounded).
        let mk_reqs = || {
            vec![
                Request::new(0, vec![1, 2, 3, 4, 5], 5), // 9 tok → 3 pages
                Request::new(1, vec![9; 8], 6),          // 13 tok → 4 pages
                Request::new(2, vec![7, 7], 2),          // 3 tok → 1 page
            ]
        };
        for admit_window in [2usize, ADMIT_SCAN_WINDOW, 0] {
            let opts = ServeOptions {
                slots_per_worker: 2,
                page_size: 4,
                kv_pages: Some(4),
                admit_window,
                ..ServeOptions::default()
            };
            let rep = serve_with(&tiny_weights(), mk_reqs(), 1, &opts).unwrap();
            assert_eq!(rep.completions.len(), 3);
            for c in &rep.completions {
                assert!(c.error.is_none(), "request {} rejected: {:?}", c.id, c.error);
            }
            let medium = &rep.completions[0];
            let big = &rep.completions[1];
            let small = &rep.completions[2];
            assert!(
                small.admitted_s < big.admitted_s,
                "small ({}) must jump the deferred big ({}) at window {admit_window}",
                small.admitted_s,
                big.admitted_s
            );
            assert!(
                big.admitted_s >= small.finished_s,
                "big only fits after earlier work retires pages"
            );
            assert!(medium.admitted_s <= small.admitted_s);
        }
        // A window of 1 sees only the deferred head, so small cannot
        // jump: it is admitted after big (the pre-fix behavior, kept
        // reachable for apples-to-apples scheduling comparisons).
        let opts = ServeOptions {
            slots_per_worker: 2,
            page_size: 4,
            kv_pages: Some(4),
            admit_window: 1,
            ..ServeOptions::default()
        };
        let rep = serve_with(&tiny_weights(), mk_reqs(), 1, &opts).unwrap();
        assert_eq!(rep.completions.len(), 3);
        let big = &rep.completions[1];
        let small = &rep.completions[2];
        assert!(
            small.admitted_s > big.admitted_s,
            "window 1 cannot scan past the deferred head"
        );
    }

    #[test]
    fn sjf_admits_short_jobs_first() {
        // One slot: whichever request is admitted first fully serializes
        // the other behind it. SJF must pick the short one even though
        // the long one arrived first.
        let mk_opts = |sched| ServeOptions {
            slots_per_worker: 1,
            sched,
            ..ServeOptions::default()
        };
        let mk_reqs = || {
            vec![
                Request::new(0, vec![3; 12], 10),
                Request::new(1, vec![5, 6], 2),
            ]
        };
        let sjf = serve_with(&tiny_weights(), mk_reqs(), 1, &mk_opts(SchedPolicy::Sjf)).unwrap();
        let (long, short) = (&sjf.completions[0], &sjf.completions[1]);
        assert!(
            short.admitted_s < long.admitted_s,
            "sjf admits the short job first ({} vs {})",
            short.admitted_s,
            long.admitted_s
        );
        let fifo = serve_with(&tiny_weights(), mk_reqs(), 1, &mk_opts(SchedPolicy::Fifo)).unwrap();
        let (long, short) = (&fifo.completions[0], &fifo.completions[1]);
        assert!(long.admitted_s < short.admitted_s, "fifo keeps arrival order");
        // Policy changes order, never tokens.
        for (a, b) in sjf.completions.iter().zip(&fifo.completions) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn token_budget_serving_matches_segregated_tokens() {
        // The token-budget scheduler is an execution schedule, not a
        // numerics change: same completions, token for token, as the
        // phase-segregated loop — while its rounds actually interleave
        // prefill chunks with decodes.
        let w = tiny_weights();
        let mk_reqs = || {
            (0..6)
                .map(|id| {
                    let prompt = (0..3 + 4 * id).map(|i| 1 + (i % 50) as u32).collect();
                    Request::new(id, prompt, 4)
                })
                .collect::<Vec<Request>>()
        };
        let seg = serve(&w, mk_reqs(), 1, 42);
        let opts = ServeOptions {
            token_budget: Some(8),
            prefill_chunk: Some(3),
            ..ServeOptions::default()
        };
        let bud = serve_with(&w, mk_reqs(), 1, &opts).unwrap();
        assert_eq!(bud.completions.len(), 6);
        for (a, b) in seg.completions.iter().zip(&bud.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "token budget must not change tokens");
        }
        assert_eq!(seg.rounds.chunked_prefill_tokens, 0);
        let total_prompt: usize = mk_reqs().iter().map(|r| r.prompt.len()).sum();
        assert_eq!(
            bud.rounds.chunked_prefill_tokens, total_prompt,
            "every prompt token streamed through in-round chunks"
        );
        assert!(
            bud.rounds.max_prefill_tokens_round <= 8,
            "rounds respect the budget: {:?}",
            bud.rounds
        );
        assert!(bud.rounds.mixed_rounds > 0, "rounds mixed decodes with chunks");
    }

    #[test]
    fn serve_reports_ttft_and_tbt_percentiles() {
        let rep = serve(&tiny_weights(), reqs(8), 2, 9);
        assert!(rep.ttft_p50_s > 0.0);
        assert!(rep.ttft_p50_s <= rep.ttft_p99_s);
        assert!(rep.tbt_p50_s > 0.0);
        assert!(rep.tbt_p50_s <= rep.tbt_p99_s);
        for c in &rep.completions {
            let ttft = c.ttft_s.expect("every served request emitted tokens");
            assert!(ttft > 0.0 && ttft <= c.total_s + 1e-9);
            assert_eq!(c.token_marks_s.len(), c.tokens.len());
            assert!(c.tbt_p99_s.expect("3 tokens → 2 gaps") >= 0.0);
        }
    }

    #[test]
    fn prefill_chunk_without_budget_is_rejected() {
        let opts = ServeOptions {
            prefill_chunk: Some(4),
            ..ServeOptions::default()
        };
        let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
        assert!(err.to_string().contains("token-budget"), "{err}");
    }

    #[test]
    fn swap_without_prefix_cache_is_rejected() {
        let opts = ServeOptions {
            swap_pages: 8,
            ..ServeOptions::default()
        };
        let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
        assert!(err.to_string().contains("prefix_cache"), "{err}");
    }

    #[test]
    fn imax_backend_reports_phases_under_serve() {
        let opts = ServeOptions {
            spec: ExecSpec::Imax(ImaxSpec::default()),
            ..ServeOptions::default()
        };
        let rep = serve_with(&tiny_weights(), reqs(3), 1, &opts).unwrap();
        assert_eq!(rep.completions.len(), 3);
        assert_eq!(rep.backend, "imax:fpga2");
        let m = rep.modeled.expect("imax backend models phases");
        assert!(m.prefill.total() > 0.0, "prefill accounted");
        assert!(m.decode.total() > 0.0, "decode accounted");
        assert!(rep.offload_ratio.unwrap() > 0.0);
        assert!(rep.streamed_bytes > 0, "modeled weight stream accounted");
        let per_tok = rep.streamed_bytes_per_token.expect("streamed bytes per token");
        assert!(per_tok > 0.0);
        assert!((per_tok - rep.streamed_bytes as f64 / rep.total_tokens as f64).abs() < 1e-9);
    }

    /// Tiny config with a 16-token vocabulary: a prompt covering the
    /// whole vocab guarantees every sampled token has a 1-gram match,
    /// so speculation verifiably fires under serve's stateful top-k
    /// samplers.
    fn spec_weights() -> ModelWeights {
        let cfg = ModelConfig {
            name: "spec-serve",
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            d_ffn: 128,
            vocab_size: 16,
            qk_norm: true,
            rope_theta: 1e4,
            rms_eps: 1e-6,
            max_seq_len: 128,
        };
        ModelWeights::random(&cfg, QuantScheme::Q8_0, 3)
    }

    #[test]
    fn speculative_serving_matches_vanilla_and_reports_acceptance() {
        let w = spec_weights();
        let mk_reqs = || {
            (0..4)
                .map(|id| Request::new(id, (0..16).collect(), 8))
                .collect::<Vec<Request>>()
        };
        let vanilla = serve(&w, mk_reqs(), 1, 42);
        assert_eq!(vanilla.verify_calls, 0);
        assert!(vanilla.accepted_tokens_per_verify.is_none());
        let opts = ServeOptions {
            speculate: 4,
            ..ServeOptions::default()
        };
        let spec = serve_with(&w, mk_reqs(), 1, &opts).unwrap();
        assert_eq!(spec.completions.len(), 4);
        // Serve samples with seeded top-k (stateful): token-for-token
        // equality pins the whole pending-token/verify protocol.
        for (a, b) in vanilla.completions.iter().zip(&spec.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "speculation must not change tokens");
        }
        assert!(spec.verify_calls > 0, "full-vocab prompts always draft");
        assert!(spec.draft_accepted <= spec.draft_tokens);
        assert!(spec.accepted_tokens_per_verify.unwrap() >= 1.0);
        // Aggregates are exactly the per-request sums.
        let sums: (usize, usize, usize) = spec.completions.iter().fold(
            (0, 0, 0),
            |(v, d, a), c| (v + c.verify_calls, d + c.draft_tokens, a + c.draft_accepted),
        );
        assert_eq!(sums, (spec.verify_calls, spec.draft_tokens, spec.draft_accepted));
    }

    #[test]
    fn drafter_without_speculation_is_rejected() {
        let opts = ServeOptions {
            drafter: Some(DrafterSpec::default()),
            ..ServeOptions::default()
        };
        let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
        assert!(err.to_string().contains("speculate"), "{err}");
    }

    #[test]
    fn heterogeneous_placement_serves_end_to_end() {
        // tiny has 4 layers: 0-1 instrumented imax, 2-3 native, across 2
        // workers — the acceptance path for `serve --backend
        // "0-N:imax,…:native"`.
        let w = tiny_weights();
        let opts = ServeOptions {
            spec: ExecSpec::parse("0-1:imax,2-3:native").unwrap(),
            ..ServeOptions::default()
        };
        let rep = serve_with(&w, reqs(5), 2, &opts).unwrap();
        assert_eq!(rep.completions.len(), 5);
        assert_eq!(rep.backend, "0-1:imax:fpga2,2-3:native");
        // Merged sub-reports: one per distinct backend, correctly labeled.
        assert_eq!(rep.per_backend.len(), 2);
        assert_eq!(rep.per_backend[0].backend, "imax:fpga2");
        assert_eq!(rep.per_backend[1].backend, "native");
        assert!(rep.per_backend[0].total_macs > 0);
        let m = rep.modeled.expect("imax share models phases");
        assert!(m.prefill.total() > 0.0 && m.decode.total() > 0.0);
        // Placement must not change the served tokens.
        let native = serve(&w, reqs(5), 1, 42);
        for (a, b) in rep.completions.iter().zip(&native.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "placement must not change tokens");
        }
    }

    #[test]
    fn placement_must_cover_the_model() {
        // tiny has 4 layers; a placement stopping at layer 2 fails fast.
        let opts = ServeOptions {
            spec: ExecSpec::parse("0-2:native").unwrap(),
            ..ServeOptions::default()
        };
        let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
        assert!(err.to_string().contains("4 layers"), "{err}");
    }

    #[test]
    fn homogeneous_serve_has_no_sub_reports() {
        let rep = serve(&tiny_weights(), reqs(2), 2, 42);
        assert!(rep.per_backend.is_empty());
    }

    #[test]
    fn audited_serve_is_clean_and_bit_identical() {
        let w = tiny_weights();
        let opts = ServeOptions {
            audit: true,
            ..ServeOptions::default()
        };
        let rep = serve_with(&w, reqs(4), 1, &opts).unwrap();
        assert_eq!(rep.completions.len(), 4);
        assert!(
            rep.audit_findings.is_empty(),
            "clean serve must verify: {:?}",
            rep.audit_findings
        );
        // The wrapper only records; execution is bit-identical.
        let plain = serve(&w, reqs(4), 1, 42);
        for (a, b) in rep.completions.iter().zip(&plain.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "audit must not change tokens");
        }
        // Without --audit the report carries no findings either way.
        assert!(plain.audit_findings.is_empty());
    }

    #[test]
    fn rejects_unavailable_backend() {
        #[cfg(not(feature = "pjrt"))]
        {
            let opts = ServeOptions {
                spec: ExecSpec::Pjrt,
                ..ServeOptions::default()
            };
            assert!(serve_with(&tiny_weights(), reqs(1), 1, &opts).is_err());
        }
    }

    #[test]
    fn streaming_delivers_every_token_live() {
        let opts = ServeOptions::default();
        let stream =
            serve_streaming(&tiny_weights(), reqs(3), 1, &opts).expect("valid opts");
        let (events, handle) = stream.into_parts();
        let events: Vec<TokenEvent> = events.iter().collect();
        let rep = handle.join().expect("serve thread panicked").unwrap();
        assert_eq!(rep.completions.len(), 3);
        assert_eq!(rep.cancelled, 0);
        assert_eq!(rep.deadline_expired, 0);
        // Every completed token arrived as exactly one event, in order,
        // with the final one flagged done.
        for c in &rep.completions {
            assert!(c.error.is_none());
            let mine: Vec<&TokenEvent> =
                events.iter().filter(|e| e.request_id == c.id).collect();
            assert_eq!(
                mine.iter().map(|e| e.token).collect::<Vec<u32>>(),
                c.tokens,
                "stream order matches the completion for request {}",
                c.id
            );
            assert!(mine.last().unwrap().done, "last event carries done");
            assert!(mine.iter().rev().skip(1).all(|e| !e.done));
            // Marks in the completion are the delivery instants the
            // stream observed.
            let marks: Vec<f64> = mine.iter().map(|e| e.mark_s).collect();
            assert_eq!(marks, c.token_marks_s, "delivery-time stamping");
            assert!(c.ttft_s.is_some());
        }
    }

    #[test]
    fn cancel_handle_tears_down_mid_serve() {
        // Long-running request with a handle cancelled after its first
        // delivered token; a short uncancelled request rides along.
        let handle = CancelHandle::new();
        let requests = vec![
            Request::new(0, vec![1, 2, 3, 4], 64).with_cancel(handle.clone()),
            Request::new(1, vec![5, 6, 7, 8], 3),
        ];
        let opts = ServeOptions::default();
        let stream =
            serve_streaming(&tiny_weights(), requests, 1, &opts).expect("valid opts");
        let (events, join) = stream.into_parts();
        let mut n_cancelled_tokens = 0usize;
        for ev in events.iter() {
            if ev.request_id == 0 {
                n_cancelled_tokens += 1;
                handle.cancel();
            }
        }
        let rep = join.join().expect("serve thread panicked").unwrap();
        assert_eq!(rep.completions.len(), 2);
        assert_eq!(rep.cancelled, 1);
        let c0 = rep.completions.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(c0.error, Some(ServeError::Cancelled));
        assert!(
            c0.tokens.len() < 64,
            "cancel must interrupt decode ({} tokens)",
            c0.tokens.len()
        );
        assert_eq!(c0.tokens.len(), n_cancelled_tokens, "delivered tokens kept");
        let c1 = rep.completions.iter().find(|c| c.id == 1).unwrap();
        assert!(c1.error.is_none());
        assert_eq!(c1.tokens.len(), 3, "other requests run to completion");
    }

    #[test]
    fn queued_cancelled_request_never_admits() {
        let handle = CancelHandle::new();
        handle.cancel();
        let requests = vec![
            Request::new(0, vec![1, 2, 3], 3).with_cancel(handle),
            Request::new(1, vec![4, 5, 6], 3),
        ];
        let rep =
            serve_with(&tiny_weights(), requests, 1, &ServeOptions::default()).unwrap();
        assert_eq!(rep.cancelled, 1);
        let c0 = rep.completions.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(c0.error, Some(ServeError::Cancelled));
        assert!(c0.tokens.is_empty(), "cancelled before admission");
        assert!(rep.completions[1].error.is_none());
    }

    #[test]
    fn expired_deadline_completes_with_typed_error() {
        // A deadline that has already passed at admission time expires
        // queue-side; a generous one never fires.
        let requests = vec![
            Request::new(0, vec![1, 2, 3], 3).with_deadline_s(0.0),
            Request::new(1, vec![4, 5, 6], 3).with_deadline_s(3600.0),
        ];
        let rep =
            serve_with(&tiny_weights(), requests, 1, &ServeOptions::default()).unwrap();
        assert_eq!(rep.deadline_expired, 1);
        let c0 = rep.completions.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(c0.error, Some(ServeError::DeadlineExpired));
        assert!(c0.tokens.is_empty());
        let c1 = rep.completions.iter().find(|c| c.id == 1).unwrap();
        assert!(c1.error.is_none());
        assert_eq!(c1.tokens.len(), 3);
    }

    #[test]
    fn dropped_receiver_cancels_all_requests() {
        let mut requests = reqs(3);
        for r in &mut requests {
            r.n_out = 64; // long enough that the drop lands mid-decode
        }
        let opts = ServeOptions::default();
        let stream =
            serve_streaming(&tiny_weights(), requests, 1, &opts).expect("valid opts");
        let (events, join) = stream.into_parts();
        // Read one event to prove the run started, then hang up.
        let first = events.recv().expect("at least one delivery");
        assert!(!first.done);
        drop(events);
        let rep = join.join().expect("serve thread panicked").unwrap();
        assert_eq!(rep.completions.len(), 3, "every request still completes");
        assert_eq!(rep.cancelled, 3);
        for c in &rep.completions {
            assert_eq!(c.error, Some(ServeError::Cancelled));
            assert!(c.tokens.len() < 64, "no request ran to completion");
        }
    }

    #[test]
    fn wfq_prioritizes_underserved_tenants_and_keeps_tokens() {
        // One slot fully serializes admissions. After tenant "bulk" is
        // served once, WFQ must put both "vip" requests (weight 100,
        // zero service) ahead of bulk's second request.
        let mk_reqs = || {
            vec![
                Request::new(0, vec![1, 2, 3, 4], 3).with_tenant("bulk"),
                Request::new(1, vec![5, 6, 7, 8], 3).with_tenant("bulk"),
                Request::new(2, vec![9, 10, 11, 12], 3).with_tenant("vip"),
                Request::new(3, vec![13, 14, 15, 16], 3).with_tenant("vip"),
            ]
        };
        let mk_opts = |sched| ServeOptions {
            slots_per_worker: 1,
            sched,
            tenant_weights: vec![("bulk".to_string(), 1.0), ("vip".to_string(), 100.0)],
            ..ServeOptions::default()
        };
        let w = tiny_weights();
        let wfq = serve_with(&w, mk_reqs(), 1, &mk_opts(SchedPolicy::Wfq)).unwrap();
        assert_eq!(wfq.completions.len(), 4);
        let at = |id: usize| {
            wfq.completions.iter().find(|c| c.id == id).expect("completed").admitted_s
        };
        assert!(at(0) < at(2), "tie at zero service keeps arrival order");
        assert!(
            at(2) < at(1) && at(3) < at(1),
            "vip overtakes bulk's second request: bulk1={} vip0={} vip1={}",
            at(1),
            at(2),
            at(3)
        );
        // Tenant tags ride through to completions.
        for c in &wfq.completions {
            let want = if c.id < 2 { "bulk" } else { "vip" };
            assert_eq!(c.tenant.as_deref(), Some(want));
        }
        // Scheduling policy is an admission order, never numerics.
        let fifo = serve_with(&w, mk_reqs(), 1, &mk_opts(SchedPolicy::Fifo)).unwrap();
        for (a, b) in wfq.completions.iter().zip(&fifo.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "wfq must not change tokens");
        }
    }

    #[test]
    fn bad_tenant_weights_are_rejected() {
        for weight in [0.0, -1.0, f64::NAN] {
            let opts = ServeOptions {
                tenant_weights: vec![("a".to_string(), weight)],
                ..ServeOptions::default()
            };
            let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
            assert!(err.to_string().contains("weight must be positive"), "{err}");
        }
        let opts = ServeOptions {
            tenant_weights: vec![(String::new(), 1.0)],
            ..ServeOptions::default()
        };
        let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
        assert!(err.to_string().contains("non-empty tenant name"), "{err}");
        for slo in [0.0, -1.0, f64::INFINITY] {
            let opts = ServeOptions { slo_ttft_s: Some(slo), ..ServeOptions::default() };
            let err = serve_with(&tiny_weights(), reqs(1), 1, &opts).unwrap_err();
            assert!(err.to_string().contains("positive number of seconds"), "{err}");
        }
    }

    #[test]
    fn timed_trace_releases_arrivals_on_schedule() {
        // The second request arrives 200 ms into the run: the feeder
        // must hold it back, so its admission lands at or after its
        // arrival instant (generous margin for the worker-epoch skew).
        let arrivals = vec![
            (Request::new(0, vec![1, 2, 3], 3), 0.0),
            (Request::new(1, vec![4, 5, 6], 3), 0.2),
        ];
        let rep =
            serve_trace(&tiny_weights(), arrivals, 1, &ServeOptions::default()).unwrap();
        assert_eq!(rep.completions.len(), 2);
        let early = rep.completions.iter().find(|c| c.id == 0).unwrap();
        let late = rep.completions.iter().find(|c| c.id == 1).unwrap();
        assert!(early.error.is_none() && late.error.is_none());
        assert_eq!(late.tokens.len(), 3);
        assert!(
            late.admitted_s >= 0.15,
            "held until its arrival instant, admitted at {}",
            late.admitted_s
        );
        assert!(late.admitted_s > early.admitted_s);
    }

    #[test]
    fn slo_grading_reports_attainment_per_tenant() {
        let mk_reqs = || {
            vec![
                Request::new(0, vec![1, 2, 3], 3).with_tenant("chat"),
                Request::new(1, vec![4, 5, 6], 3).with_tenant("rag"),
            ]
        };
        let w = tiny_weights();
        // A generous TTFT target: everything served attains it.
        let opts = ServeOptions { slo_ttft_s: Some(3600.0), ..ServeOptions::default() };
        let rep = serve_with(&w, mk_reqs(), 1, &opts).unwrap();
        assert_eq!(rep.slo_ttft_s, Some(3600.0));
        assert_eq!(rep.slo_attainment, Some(1.0));
        assert_eq!(rep.tenants.len(), 2);
        let chat = rep.tenants.iter().find(|t| t.tenant == "chat").unwrap();
        assert_eq!((chat.requests, chat.served, chat.total_tokens), (1, 1, 3));
        assert_eq!((chat.cancelled, chat.deadline_expired, chat.rejected), (0, 0, 0));
        assert!(chat.ttft_p50_s > 0.0 && chat.ttft_p50_s <= chat.ttft_p99_s);
        assert_eq!(chat.slo_attainment, Some(1.0));
        // An unattainable TBT target: nothing attains it.
        let opts = ServeOptions { slo_tbt_s: Some(1e-12), ..ServeOptions::default() };
        let rep = serve_with(&w, mk_reqs(), 1, &opts).unwrap();
        assert_eq!(rep.slo_attainment, Some(0.0));
        for t in &rep.tenants {
            assert_eq!(t.slo_attainment, Some(0.0), "tenant {}", t.tenant);
        }
        // Untagged runs without targets keep the flat report shape.
        let rep = serve(&w, reqs(2), 1, 42);
        assert!(rep.tenants.is_empty());
        assert_eq!(rep.slo_attainment, None);
    }

    #[test]
    fn adaptive_budget_tracks_modeled_balance() {
        let w = tiny_weights();
        let mk_reqs = || {
            (0..6)
                .map(|id| {
                    let prompt = (0..3 + 4 * id).map(|i| 1 + (i % 50) as u32).collect();
                    Request::new(id, prompt, 4)
                })
                .collect::<Vec<Request>>()
        };
        let opts = ServeOptions {
            spec: ExecSpec::Imax(ImaxSpec::default()),
            adaptive_budget: Some(AdaptiveBudget::new(4, 64)),
            prefill_chunk: Some(3),
            adaptive_chunk: true,
            ..ServeOptions::default()
        };
        let rep = serve_with(&w, mk_reqs(), 1, &opts).unwrap();
        assert_eq!(rep.completions.len(), 6);
        assert!(
            rep.rounds.adaptive_rounds > 0,
            "modeled backend re-budgets every settled round: {:?}",
            rep.rounds
        );
        let (lo, hi) = (rep.rounds.budget_lo, rep.rounds.budget_hi);
        assert!(
            (4..=64).contains(&lo) && (4..=64).contains(&hi) && lo <= hi,
            "controller stays inside [4, 64]: lo={lo} hi={hi}"
        );
        // The controller moves the schedule, never the numerics: token
        // for token identical to a fixed-budget run.
        let fixed = ServeOptions {
            spec: ExecSpec::Imax(ImaxSpec::default()),
            token_budget: Some(8),
            prefill_chunk: Some(3),
            ..ServeOptions::default()
        };
        let base = serve_with(&w, mk_reqs(), 1, &fixed).unwrap();
        for (a, b) in rep.completions.iter().zip(&base.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "adaptive budget must not change tokens");
        }
        // A functional backend feeds no modeled balance: the budget
        // freezes at its starting point and the trace stays empty.
        let nat = ServeOptions {
            adaptive_budget: Some(AdaptiveBudget::new(4, 64)),
            ..ServeOptions::default()
        };
        let rep = serve_with(&w, mk_reqs(), 1, &nat).unwrap();
        assert_eq!(rep.completions.len(), 6);
        assert_eq!(rep.rounds.adaptive_rounds, 0, "native backend never re-budgets");
    }
}
