//! Scheduling: the continuous-batching session scheduler that drives the
//! serving loop, plus the Fig 16 lane-scalability sweep.
//!
//! **Continuous batching** ([`ContinuousBatcher`]): one engine with
//! several KV-cache session slots serves many requests concurrently —
//! new requests are admitted into free slots *between decode rounds*, so
//! a request that arrives mid-run starts prefilling and decoding while
//! earlier requests are still generating (vLLM-style iteration-level
//! scheduling; cf. the host-side serving structure of the paper's §III.A
//! where the Arm host multiplexes llama.cpp contexts). The batcher is
//! single-threaded and deterministic; `coordinator::serve` runs one per
//! worker thread over a shared queue.
//!
//! **Lane scalability** ([`lane_sweep`], paper Fig 16 / §V.C): the FPGA
//! carries 8 IMAX lanes, but the dual-core A72 host saturates beyond
//! two — the scheduler model distributes kernel rows across lanes (EXEC
//! speedup) while the host-contention factor in [`crate::imax::sim`]
//! inflates HOST/LOAD issue costs, reproducing the saturation curve.

use std::time::Instant;

use crate::coordinator::hybrid::{simulate, Workload, WorkloadRun};
use crate::coordinator::offload::OffloadPolicy;
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::lmm::LmmConfig;
use crate::model::engine::{Engine, MatvecExec, Session};
use crate::model::graph::Phase;
use crate::model::sampler::Sampler;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub n_out: usize,
}

/// Lifecycle record of one served request, timestamped on the serving
/// epoch's clock (seconds since `ContinuousBatcher::new`'s `epoch`).
#[derive(Clone, Debug)]
pub struct SessionLog {
    pub id: usize,
    pub tokens: Vec<u32>,
    pub n_prefill: usize,
    /// Time spent in the shared queue before admission.
    pub queue_s: f64,
    /// Prefill / decode processing time attributed to this request.
    pub prefill_s: f64,
    pub decode_s: f64,
    /// Epoch-relative lifecycle marks.
    pub admitted_s: f64,
    pub decode_start_s: f64,
    pub finished_s: f64,
}

/// One in-flight request: its session, latest logits, and timing.
struct InFlight {
    req: Request,
    session: Session,
    logits: Vec<f32>,
    tokens: Vec<u32>,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    admitted_s: f64,
    decode_start_s: f64,
}

impl InFlight {
    /// Split into the session (returned to the engine's slot pool) and
    /// the request's lifecycle log.
    fn finish(self, finished_s: f64) -> (Session, SessionLog) {
        let InFlight {
            req,
            session,
            logits: _,
            tokens,
            queue_s,
            prefill_s,
            decode_s,
            admitted_s,
            decode_start_s,
        } = self;
        let log = SessionLog {
            id: req.id,
            n_prefill: req.prompt.len(),
            tokens,
            queue_s,
            prefill_s,
            decode_s,
            admitted_s,
            decode_start_s,
            finished_s,
        };
        (session, log)
    }
}

/// Iteration-level scheduler for one worker: admit → prefill as ubatches
/// → interleaved decode rounds, over the engine's session slots.
pub struct ContinuousBatcher {
    engine: Engine,
    ubatch: usize,
    epoch: Instant,
    active: Vec<InFlight>,
}

impl ContinuousBatcher {
    /// `epoch` is the serving run's start instant (shared across workers
    /// so every `SessionLog` sits on one timeline).
    pub fn new(engine: Engine, ubatch: usize, epoch: Instant) -> ContinuousBatcher {
        assert!(ubatch >= 1);
        ContinuousBatcher {
            engine,
            ubatch,
            epoch,
            active: Vec::new(),
        }
    }

    /// Free session slots (how many more requests can be admitted).
    pub fn capacity(&self) -> usize {
        self.engine.free_sessions()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Admit one request into a free slot and run its prefill (as ubatch
    /// chunks). Requires `capacity() > 0`. Returns the finished log
    /// immediately for degenerate `n_out == 0` requests.
    pub fn admit(
        &mut self,
        req: Request,
        sampler: Sampler,
        queue_s: f64,
        exec: &mut dyn MatvecExec,
    ) -> Option<SessionLog> {
        let session = self
            .engine
            .open_session(sampler)
            .expect("admit() requires capacity() > 0");
        let admitted_s = self.epoch.elapsed().as_secs_f64();
        let tp0 = Instant::now();
        let logits = self
            .engine
            .prefill_session(&session, &req.prompt, self.ubatch, exec);
        let prefill_s = tp0.elapsed().as_secs_f64();
        let inflight = InFlight {
            req,
            session,
            logits,
            tokens: Vec::new(),
            queue_s,
            prefill_s,
            decode_s: 0.0,
            admitted_s,
            decode_start_s: admitted_s + prefill_s,
        };
        if inflight.req.n_out == 0 {
            let finished_s = self.epoch.elapsed().as_secs_f64();
            let (session, mut log) = inflight.finish(finished_s);
            self.engine.close_session(session);
            // A 0-output request never decodes; pin its decode mark to
            // its finish time so interval arithmetic stays well-formed.
            log.decode_start_s = log.finished_s;
            return Some(log);
        }
        self.active.push(inflight);
        None
    }

    /// One decode step for every active request, in admission order;
    /// requests that reach their `n_out` are retired and returned. Each
    /// request samples exactly `n_out` tokens over its lifetime (the
    /// final sampled token needs no further forward pass).
    pub fn decode_round(&mut self, exec: &mut dyn MatvecExec) -> Vec<SessionLog> {
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let td0 = Instant::now();
            let f = &mut self.active[i];
            if f.tokens.is_empty() {
                f.decode_start_s = self.epoch.elapsed().as_secs_f64();
            }
            let next = f.session.sampler.sample(&f.logits);
            f.tokens.push(next);
            let done = f.tokens.len() == f.req.n_out;
            if !done {
                f.logits = self
                    .engine
                    .forward_session(&f.session, next, Phase::Decode, true, exec)
                    .expect("decode produced logits");
            }
            self.active[i].decode_s += td0.elapsed().as_secs_f64();
            if done {
                let f = self.active.remove(i);
                let finished_s = self.epoch.elapsed().as_secs_f64();
                let (session, log) = f.finish(finished_s);
                self.engine.close_session(session);
                finished.push(log);
            } else {
                i += 1;
            }
        }
        finished
    }

    /// Drain every active request to completion (no further admissions).
    pub fn drain(&mut self, exec: &mut dyn MatvecExec) -> Vec<SessionLog> {
        let mut out = Vec::new();
        while self.n_active() > 0 {
            out.extend(self.decode_round(exec));
        }
        out
    }
}

/// One point of the Fig 16 sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub lanes: usize,
    pub e2e_s: f64,
    pub tokens_per_s: f64,
    pub exec_s: f64,
    pub host_s: f64,
    pub run: WorkloadRun,
}

/// Sweep lane counts for a workload on a device family.
pub fn lane_sweep(
    w: &Workload,
    base: &ImaxDevice,
    lanes: &[usize],
    mode: TransferMode,
) -> Vec<ScalingPoint> {
    lanes
        .iter()
        .map(|&n| {
            let dev = base.clone().with_lanes(n);
            let policy =
                OffloadPolicy::for_workload(&dev, &w.cfg, w.scheme, LmmConfig::new(dev.lmm_kb));
            let run = simulate(w, &dev, &policy, mode);
            let total = run.breakdown.total();
            let e2e = run.breakdown.e2e_seconds();
            ScalingPoint {
                lanes: n,
                e2e_s: e2e,
                tokens_per_s: (w.n_in + w.n_out) as f64 / e2e,
                exec_s: total.exec,
                host_s: total.host,
                run,
            }
        })
        .collect()
}

/// The lane count with the best E2E latency in a sweep.
pub fn best_lanes(points: &[ScalingPoint]) -> usize {
    points
        .iter()
        .min_by(|a, b| a.e2e_s.partial_cmp(&b.e2e_s).unwrap())
        .map(|p| p.lanes)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::NativeExec;
    use crate::model::weights::ModelWeights;

    fn workload() -> Workload {
        Workload {
            cfg: ModelConfig::qwen3_0_6b(),
            scheme: QuantScheme::Q3KS,
            n_in: 32,
            n_out: 16,
        }
    }

    fn tiny_weights() -> ModelWeights {
        ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 11)
    }

    #[test]
    fn batcher_matches_generate() {
        let weights = tiny_weights();
        let prompt = vec![1u32, 5, 9, 2, 7];
        let n_out = 6;

        let mut b = ContinuousBatcher::new(
            Engine::with_slots(weights.clone(), 2),
            3,
            Instant::now(),
        );
        let mut exec = NativeExec;
        let req = Request { id: 0, prompt: prompt.clone(), n_out };
        assert!(b.admit(req, Sampler::greedy(), 0.0, &mut exec).is_none());
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 1);

        let mut reference = Engine::new(weights);
        let want = reference.generate(&prompt, n_out, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(logs[0].tokens, want.tokens, "batcher must match generate");
        assert_eq!(logs[0].n_prefill, prompt.len());
        assert!(logs[0].decode_start_s >= logs[0].admitted_s);
        assert!(logs[0].finished_s >= logs[0].decode_start_s);
    }

    #[test]
    fn mid_run_admission_interleaves() {
        // The continuous-batching property, deterministically: a request
        // admitted after another has started decoding finishes its own
        // decode before the earlier request completes.
        let weights = tiny_weights();
        let mut b =
            ContinuousBatcher::new(Engine::with_slots(weights, 2), 32, Instant::now());
        let mut exec = NativeExec;

        let r0 = Request { id: 0, prompt: vec![1, 2, 3], n_out: 8 };
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec);
        // r0 decodes a few rounds alone…
        for _ in 0..3 {
            assert!(b.decode_round(&mut exec).is_empty());
        }
        // …then r1 arrives mid-run and joins the same engine.
        let r1 = Request { id: 1, prompt: vec![9, 8], n_out: 2 };
        b.admit(r1, Sampler::greedy(), 0.0, &mut exec);
        assert_eq!(b.n_active(), 2);

        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        let (l0, l1) = (&logs[0], &logs[1]);
        assert_eq!(l0.tokens.len(), 8);
        assert_eq!(l1.tokens.len(), 2);
        assert!(
            l1.admitted_s > l0.decode_start_s,
            "r1 admitted after r0 started decoding"
        );
        assert!(
            l1.finished_s < l0.finished_s,
            "short r1 finishes while long r0 is still decoding"
        );
    }

    #[test]
    fn zero_output_request_finishes_at_admit() {
        let weights = tiny_weights();
        let mut b =
            ContinuousBatcher::new(Engine::with_slots(weights, 1), 32, Instant::now());
        let req = Request { id: 7, prompt: vec![1, 2], n_out: 0 };
        let log = b
            .admit(req, Sampler::greedy(), 0.0, &mut NativeExec)
            .expect("finishes immediately");
        assert_eq!(log.id, 7);
        assert!(log.tokens.is_empty());
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.capacity(), 1, "slot released");
    }

    #[test]
    fn performance_saturates_beyond_two_lanes() {
        // Paper Fig 16: 1 → 2 lanes improves; ≥4 lanes degrades on the
        // dual-core host.
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[1, 2, 4, 8],
            TransferMode::Coalesced,
        );
        assert!(pts[1].e2e_s < pts[0].e2e_s, "2 lanes beat 1");
        assert!(pts[2].e2e_s > pts[1].e2e_s, "4 lanes degrade vs 2");
        assert!(pts[3].e2e_s > pts[2].e2e_s, "8 lanes degrade further");
        assert_eq!(best_lanes(&pts), 2, "paper's chosen configuration");
    }

    #[test]
    fn exec_time_monotonically_decreases_with_lanes() {
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[1, 2, 4, 8],
            TransferMode::Coalesced,
        );
        for w in pts.windows(2) {
            assert!(
                w[1].exec_s < w[0].exec_s,
                "EXEC itself scales: {} vs {}",
                w[1].exec_s,
                w[0].exec_s
            );
        }
    }

    #[test]
    fn host_time_grows_beyond_host_cores() {
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[2, 8],
            TransferMode::Coalesced,
        );
        assert!(pts[1].host_s > pts[0].host_s);
    }
}
