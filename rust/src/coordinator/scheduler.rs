//! Lane scalability analysis (paper Fig 16 / §V.C).
//!
//! The FPGA carries 8 IMAX lanes, but the dual-core A72 host saturates
//! beyond two: "performance saturates and then degrades beyond a two-lane
//! configuration ... a direct consequence of the dual-core ARM host's
//! limited capability to manage data transfers and control flow for
//! multiple parallel lanes." The scheduler model distributes kernel rows
//! across lanes (EXEC speedup) while the host-contention factor in
//! [`crate::imax::sim`] inflates HOST/LOAD issue costs — reproducing the
//! saturation curve.

use crate::coordinator::hybrid::{simulate, Workload, WorkloadRun};
use crate::coordinator::offload::OffloadPolicy;
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::lmm::LmmConfig;

/// One point of the Fig 16 sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub lanes: usize,
    pub e2e_s: f64,
    pub tokens_per_s: f64,
    pub exec_s: f64,
    pub host_s: f64,
    pub run: WorkloadRun,
}

/// Sweep lane counts for a workload on a device family.
pub fn lane_sweep(
    w: &Workload,
    base: &ImaxDevice,
    lanes: &[usize],
    mode: TransferMode,
) -> Vec<ScalingPoint> {
    lanes
        .iter()
        .map(|&n| {
            let dev = base.clone().with_lanes(n);
            let policy =
                OffloadPolicy::for_workload(&dev, &w.cfg, w.scheme, LmmConfig::new(dev.lmm_kb));
            let run = simulate(w, &dev, &policy, mode);
            let total = run.breakdown.total();
            let e2e = run.breakdown.e2e_seconds();
            ScalingPoint {
                lanes: n,
                e2e_s: e2e,
                tokens_per_s: (w.n_in + w.n_out) as f64 / e2e,
                exec_s: total.exec,
                host_s: total.host,
                run,
            }
        })
        .collect()
}

/// The lane count with the best E2E latency in a sweep.
pub fn best_lanes(points: &[ScalingPoint]) -> usize {
    points
        .iter()
        .min_by(|a, b| a.e2e_s.partial_cmp(&b.e2e_s).unwrap())
        .map(|p| p.lanes)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};

    fn workload() -> Workload {
        Workload {
            cfg: ModelConfig::qwen3_0_6b(),
            scheme: QuantScheme::Q3KS,
            n_in: 32,
            n_out: 16,
        }
    }

    #[test]
    fn performance_saturates_beyond_two_lanes() {
        // Paper Fig 16: 1 → 2 lanes improves; ≥4 lanes degrades on the
        // dual-core host.
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[1, 2, 4, 8],
            TransferMode::Coalesced,
        );
        assert!(pts[1].e2e_s < pts[0].e2e_s, "2 lanes beat 1");
        assert!(pts[2].e2e_s > pts[1].e2e_s, "4 lanes degrade vs 2");
        assert!(pts[3].e2e_s > pts[2].e2e_s, "8 lanes degrade further");
        assert_eq!(best_lanes(&pts), 2, "paper's chosen configuration");
    }

    #[test]
    fn exec_time_monotonically_decreases_with_lanes() {
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[1, 2, 4, 8],
            TransferMode::Coalesced,
        );
        for w in pts.windows(2) {
            assert!(
                w[1].exec_s < w[0].exec_s,
                "EXEC itself scales: {} vs {}",
                w[1].exec_s,
                w[0].exec_s
            );
        }
    }

    #[test]
    fn host_time_grows_beyond_host_cores() {
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[2, 8],
            TransferMode::Coalesced,
        );
        assert!(pts[1].host_s > pts[0].host_s);
    }
}
