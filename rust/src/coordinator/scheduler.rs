//! Scheduling: the continuous-batching session scheduler that drives the
//! serving loop, plus the Fig 16 lane-scalability sweep.
//!
//! **Continuous batching** ([`ContinuousBatcher`]): one engine with
//! several KV-cache session slots serves many requests concurrently —
//! new requests are admitted into free slots *between decode rounds*, so
//! a request that arrives mid-run starts prefilling and decoding while
//! earlier requests are still generating (vLLM-style iteration-level
//! scheduling; cf. the host-side serving structure of the paper's §III.A
//! where the Arm host multiplexes llama.cpp contexts). The batcher is
//! single-threaded and deterministic; `coordinator::serve` runs one per
//! worker thread over a shared queue.
//!
//! **Page-budget admission**: with the paged KV cache the binding
//! resource is pages, not slots. [`ContinuousBatcher::admit`] commits the
//! worst case of each request (`prompt + n_out − 1` cached tokens, in
//! pages) against the pool before admitting, so concurrently live
//! sequences can never exhaust the pool mid-decode — a request that does
//! not fit *right now* is [`Admitted::Deferred`] back to the caller for
//! retry after decode rounds retire sequences, and a request that can
//! *never* fit (pages above the whole pool, or tokens above the context
//! window) is rejected with [`AdmitError::TooLarge`] instead of wedging
//! the queue.
//!
//! **Prefix-aware, dedup-exact accounting**: when the engine's prefix
//! cache is on, admission first aliases the cached page-aligned prefix
//! of the prompt ([`crate::model::Engine::adopt_prefix`]), then gates on
//! the *exact distinct* worst-case demand of the live set: every
//! request's fresh pages (worst case minus its aliased pages — the pages
//! it will allocate itself) plus one unit per distinct aliased page that
//! no live request self-allocated. A page shared by N live sequences is
//! therefore committed exactly once — never double-counted against its
//! allocator — and when an allocator finishes while sharers live, the
//! recomputation transfers its coverage to the shared unit. Unpinned
//! cached pages are excluded entirely: the cache reclaims them on demand
//! (evicting to the host swap arena when configured), which is how
//! oversubscription beyond the physical pool stays safe.
//!
//! **Admission policy** ([`SchedPolicy`]): the serving queue scan admits
//! FIFO by default, or shortest-job-first by *prefix-aware effective
//! cost* ([`ContinuousBatcher::effective_cost_pages`]) — worst-case
//! pages minus the currently cached prefix — which drops p95 latency
//! under mixed prompt lengths.
//!
//! **Token-budget iteration scheduling**
//! ([`ContinuousBatcher::with_token_budget`]): by default admission runs
//! a request's whole prefill before the next decode round, so one long
//! prompt stalls every live decode (the head-of-line pathology the
//! paper's host-bound serving loop is most exposed to). With a token
//! budget set, every round instead assembles a *mixed batch* of at most
//! `token_budget` tokens: all live decode tokens first — the
//! decode-starvation guarantee, a round with any live decode always
//! carries every one of them — then resumable prefill chunks
//! ([`PrefillCursor`], at most `prefill_chunk` tokens each, capped by
//! the remaining budget) from admitted-but-unprefilled slots. Long
//! prompts therefore interleave with live decodes, bounding the
//! worst-case gap between a request's tokens (p99 time-between-tokens)
//! by one chunk instead of one whole prompt, while staying bit-identical
//! to the phase-segregated schedule (chunk boundaries are an execution
//! schedule, not a numerics change). Per-round token counts are kept in
//! [`RoundTokens`] / [`RoundStats`], and each settled round is marked on
//! the executor via [`KernelExec::round_boundary`] so the instrumented
//! cost model keeps the modeled transfer bottleneck visible per round.
//!
//! **Speculative decoding**
//! ([`ContinuousBatcher::with_speculation`]): vanilla decode streams
//! every offloaded weight for one token of useful work — the paper's
//! LOAD-bound regime at its worst. With speculation on, each live
//! decode drafts up to k continuation tokens per round (cheap n-gram
//! prompt lookup, [`crate::model::drafter::NgramDrafter`], seeded from
//! the request's prompt + generated history and the prefix cache's
//! committed spans) and verifies the whole draft in **one** batched
//! ubatch ([`crate::model::Engine::try_verify_session`]). Acceptance
//! replays the session's own sampler over the per-position verify
//! logits in vanilla order, so output is bit-identical to vanilla
//! decode by construction (greedy *and* seeded top-k): accepted tokens
//! keep their cached KV, the first mismatch rolls the rejected tail
//! back through the paged pool's truncate path (refcount/CoW-safe),
//! and the final sampled token of every verify — the bonus on full
//! acceptance, the sampler's own choice on mismatch — stays *pending*:
//! it is emitted now but forwarded by the next round, which skips its
//! initial sample so stateful samplers advance exactly once per token.
//! Drafted tokens are budgeted tokens: the mandatory one-token decode
//! stays starvation-exempt, while the speculative extension spends
//! only what the round's token budget still allows, competing fairly
//! with prefill chunks. Every accepted token is one more token per
//! round of streamed weights — decode moves toward the prefill regime,
//! which is exactly the trade the CGLA cost model rewards.
//!
//! **Streaming delivery, cancellation and deadlines**: with a delivery
//! sink attached ([`ContinuousBatcher::with_delivery`]) every sampled
//! token is pushed to the consumer as a [`TokenEvent`] the moment the
//! scheduler makes it available, and all latency marks are stamped at
//! *delivery* — `token_marks_s` records when each token reached the
//! sink, and `delivery_marks_s` records one instant per sink *event*
//! (a speculative verify emits its accepted run as one event), which
//! is what [`SessionLog::tbt_gaps_s`] measures. Requests can carry a
//! [`CancelHandle`] and/or a relative deadline
//! ([`Request::with_deadline_s`]); [`ContinuousBatcher::reap`] runs at
//! every round boundary and tears down cancelled or expired flights
//! through the refcounted release path — mid-[`PrefillCursor`] and
//! pending-verify states included — freeing exactly their non-shared
//! pages (registered prefix pages stay adoptable) and returning the
//! slot so the same round's budget is spent by the surviving requests.
//!
//! **Lane scalability** ([`lane_sweep`], paper Fig 16 / §V.C): the FPGA
//! carries 8 IMAX lanes, but the dual-core A72 host saturates beyond
//! two — the scheduler model distributes kernel rows across lanes (EXEC
//! speedup) while the host-contention factor in [`crate::imax::sim`]
//! inflates HOST/LOAD issue costs, reproducing the saturation curve.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::hybrid::{simulate, Workload, WorkloadRun};
use crate::coordinator::offload::OffloadPolicy;
use crate::imax::device::ImaxDevice;
use crate::imax::dma::TransferMode;
use crate::imax::lmm::LmmConfig;
use crate::model::drafter::{DrafterSpec, NgramDrafter};
use crate::model::engine::{Engine, KernelExec, PrefillCursor, RoundBalance, Session};
use crate::model::graph::Phase;
use crate::model::kv_cache::{CacheError, KvReuseStats};
use crate::model::sampler::Sampler;

/// Queue admission order for the serving loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Arrival order (a deferred head is retried first).
    Fifo,
    /// Shortest job first within the scan window, by prefix-aware
    /// effective cost (worst-case pages minus the cached prefix).
    Sjf,
    /// Weighted fair queueing across tenants within the scan window:
    /// candidates whose tenant has consumed the least weighted service
    /// admit first (see [`TenantFairness`]), so one tenant's burst
    /// cannot starve another's steady trickle. Requests without a
    /// tenant share one default account at weight 1.
    Wfq,
}

impl SchedPolicy {
    /// Parse a `--sched` value (`fifo|sjf|wfq`), case-insensitive.
    pub fn by_name(name: &str) -> Option<SchedPolicy> {
        match name.to_ascii_lowercase().as_str() {
            "fifo" => Some(SchedPolicy::Fifo),
            "sjf" => Some(SchedPolicy::Sjf),
            "wfq" => Some(SchedPolicy::Wfq),
            _ => None,
        }
    }

    /// The CLI name of this policy.
    pub fn name(self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::Sjf => "sjf",
            SchedPolicy::Wfq => "wfq",
        }
    }
}

/// Weighted fair-queueing ledger for per-tenant admission
/// ([`SchedPolicy::Wfq`]): each tenant accrues *virtual service* —
/// admitted tokens divided by its weight — and admission always prefers
/// the candidate whose tenant has accrued the least. A tenant with
/// weight 2 therefore sustains twice the admitted token rate of a
/// weight-1 tenant under contention, and a burst from one tenant cannot
/// monopolize the scan window: its virtual service races ahead after a
/// few admissions and the other tenants' requests sort first.
///
/// Requests without a tenant share one default account at weight 1.0.
/// The ledger is deliberately engine-agnostic (plain names and token
/// counts) so benches can drive it against a bare
/// [`ContinuousBatcher`] exactly the way the serve loop does.
#[derive(Clone, Debug, Default)]
pub struct TenantFairness {
    weights: HashMap<String, f64>,
    service: HashMap<String, f64>,
}

impl TenantFairness {
    /// Build a ledger from `(tenant, weight)` pairs. Non-positive
    /// weights are clamped to a small epsilon (a zero weight would make
    /// one admission push the tenant's virtual service to infinity).
    pub fn new(weights: &[(String, f64)]) -> TenantFairness {
        let weights = weights
            .iter()
            .map(|(name, w)| (name.clone(), w.max(1e-9)))
            .collect();
        TenantFairness { weights, service: HashMap::new() }
    }

    fn key(tenant: Option<&str>) -> &str {
        tenant.unwrap_or("")
    }

    /// The admission weight of `tenant` (1.0 unless configured).
    pub fn weight(&self, tenant: Option<&str>) -> f64 {
        self.weights.get(Self::key(tenant)).copied().unwrap_or(1.0)
    }

    /// Weighted service `tenant` has accrued so far (admitted tokens
    /// divided by its weight).
    pub fn virtual_service(&self, tenant: Option<&str>) -> f64 {
        self.service.get(Self::key(tenant)).copied().unwrap_or(0.0)
    }

    /// Admission order over a window of candidates, least-served tenant
    /// first. The sort is stable, so requests of one tenant (and ties
    /// across fresh tenants) keep arrival order.
    pub fn order(&self, tenants: &[Option<&str>]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..tenants.len()).collect();
        order.sort_by(|&a, &b| {
            let sa = self.virtual_service(tenants[a]);
            let sb = self.virtual_service(tenants[b]);
            sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal)
        });
        order
    }

    /// Charge an admission: `tokens` of work (prompt + requested output
    /// tokens) against `tenant`'s weighted account.
    pub fn charge(&mut self, tenant: Option<&str>, tokens: usize) {
        let w = self.weight(tenant);
        *self.service.entry(Self::key(tenant).to_string()).or_insert(0.0) +=
            tokens as f64 / w;
    }
}

/// Closed-loop per-round token budget
/// ([`ContinuousBatcher::with_adaptive_budget`]): after every settled
/// round the controller reads the backend's modeled LOAD/EXEC balance
/// ([`KernelExec::last_round_balance`]) and walks the budget inside
/// `[min, max]` — up when the round was LOAD-bound (a bigger round
/// amortizes the same weight stream over more tokens, the paper's
/// transfer-bottleneck lever), down when EXEC-bound (extra tokens are
/// pure latency). Functional backends feed no balance, so the budget
/// stays at its starting value and scheduling remains exactly the fixed
/// `--token-budget` behavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveBudget {
    /// Budget floor: the controller never starves prefill below this.
    pub min: usize,
    /// Budget ceiling: bounds the worst-case round latency (TBT).
    pub max: usize,
    /// LOAD fraction at or below which the budget shrinks one step.
    pub low_load_frac: f64,
    /// LOAD fraction at or above which the budget grows one step.
    pub high_load_frac: f64,
}

impl AdaptiveBudget {
    /// Controller with the default dead-band (shrink ≤ 0.45, grow
    /// ≥ 0.65). Panics unless `1 <= min <= max`.
    pub fn new(min: usize, max: usize) -> AdaptiveBudget {
        assert!(min >= 1, "adaptive budget floor must be at least 1");
        assert!(min <= max, "adaptive budget floor must not exceed its ceiling");
        AdaptiveBudget { min, max, low_load_frac: 0.45, high_load_frac: 0.65 }
    }

    /// Parse the CLI form `MIN:MAX` (e.g. `--adaptive-budget 4:64`).
    pub fn parse(s: &str) -> anyhow::Result<AdaptiveBudget> {
        let (min, max) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("adaptive budget must be MIN:MAX, got '{s}'"))?;
        let min: usize = min.trim().parse()?;
        let max: usize = max.trim().parse()?;
        if min < 1 || min > max {
            anyhow::bail!("adaptive budget needs 1 <= MIN <= MAX, got {min}:{max}");
        }
        Ok(AdaptiveBudget::new(min, max))
    }

    /// One controller step: the next round's budget given the current
    /// one and the settled round's LOAD/EXEC balance. Multiplicative
    /// steps (a quarter of the current budget, at least 1 token) so the
    /// budget converges in a handful of rounds from either end.
    pub fn next_budget(&self, cur: usize, bal: &RoundBalance) -> usize {
        let Some(frac) = bal.load_fraction() else {
            return cur.clamp(self.min, self.max);
        };
        let step = (cur / 4).max(1);
        if frac >= self.high_load_frac {
            (cur + step).min(self.max)
        } else if frac <= self.low_load_frac {
            cur.saturating_sub(step).max(self.min)
        } else {
            cur.clamp(self.min, self.max)
        }
    }
}

/// Cooperative cancellation latch for one request, shared between the
/// submitter and the scheduler. Cancelling is one-way and checked at
/// round boundaries: the flight is torn down by the next
/// [`ContinuousBatcher::reap`] (its pages released through the
/// refcount/CoW path), never mid-kernel.
#[derive(Clone, Debug, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    /// Fresh, un-cancelled latch.
    pub fn new() -> CancelHandle {
        CancelHandle::default()
    }

    /// Latch the cancel; takes effect at the next round boundary.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Whether [`CancelHandle::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen identifier carried through logs and completions.
    pub id: usize,
    /// Prompt token ids to prefill.
    pub prompt: Vec<u32>,
    /// Number of tokens to decode after the prompt.
    pub n_out: usize,
    /// Relative deadline in seconds, measured from the instant the
    /// request entered the serving queue: once exceeded — in the queue
    /// or mid-decode — the request completes with a typed error and its
    /// pages are released. `None` = no deadline.
    pub deadline_s: Option<f64>,
    /// Cooperative cancellation latch (e.g. a consumer that dropped its
    /// stream receiver), checked between rounds. `None` = not
    /// cancellable.
    pub cancel: Option<CancelHandle>,
    /// Tenant class this request belongs to (`None` = untagged).
    /// Carried through [`SessionLog`] into the serve report's
    /// per-tenant latency/SLO breakdown, and the account
    /// [`SchedPolicy::Wfq`] admission charges.
    pub tenant: Option<String>,
}

impl Request {
    /// An untenanted, uncancellable request with no deadline.
    pub fn new(id: usize, prompt: Vec<u32>, n_out: usize) -> Request {
        Request { id, prompt, n_out, deadline_s: None, cancel: None, tenant: None }
    }

    /// Attach a relative deadline (seconds from enqueue).
    pub fn with_deadline_s(mut self, deadline_s: f64) -> Request {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Attach a cancellation latch.
    pub fn with_cancel(mut self, handle: CancelHandle) -> Request {
        self.cancel = Some(handle);
        self
    }

    /// Tag the request with a tenant class name.
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> Request {
        self.tenant = Some(tenant.into());
        self
    }

    /// Whether the attached latch (if any) has been cancelled.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.as_ref().map_or(false, CancelHandle::is_cancelled)
    }
}

/// One delivered token, pushed to the serving stream the moment the
/// scheduler makes it consumer-visible (the SSE `{content, done}`
/// delivery shape, token-level).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TokenEvent {
    /// [`Request::id`] of the originating request.
    pub request_id: usize,
    /// The sampled token id.
    pub token: u32,
    /// Epoch-relative delivery instant — the mark TTFT/TBT percentiles
    /// are computed from.
    pub mark_s: f64,
    /// True on the request's final token (its `n_out`-th; a cancelled
    /// or expired request's stream simply stops without a `done`
    /// event — the completion channel carries the typed outcome).
    pub done: bool,
}

/// Per-token delivery callback. Returning `false` signals the consumer
/// is gone (e.g. a dropped channel receiver): the batcher latches
/// delivery-closed and cancels every in-flight request at the next
/// round boundary.
pub type DeliverySink = Box<dyn FnMut(TokenEvent) -> bool + Send>;

/// How a request left the batcher.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled all `n_out` tokens.
    Completed,
    /// Torn down by its [`CancelHandle`] (or a closed delivery sink).
    Cancelled,
    /// Torn down because its [`Request::deadline_s`] expired.
    DeadlineExpired,
}

/// Lifecycle record of one served request, timestamped on the serving
/// epoch's clock (seconds since `ContinuousBatcher::new`'s `epoch`).
#[derive(Clone, Debug)]
pub struct SessionLog {
    /// [`Request::id`] of the originating request.
    pub id: usize,
    /// Tenant class of the originating [`Request`] (`None` = untagged).
    pub tenant: Option<String>,
    /// Every token the request decoded (or kept at teardown).
    pub tokens: Vec<u32>,
    /// Prompt tokens actually prefilled (prefix-cache hits skip some).
    pub n_prefill: usize,
    /// Time spent in the shared queue before admission.
    pub queue_s: f64,
    /// Prefill processing time attributed to this request.
    pub prefill_s: f64,
    /// Decode processing time attributed to this request.
    pub decode_s: f64,
    /// Epoch-relative admission mark.
    pub admitted_s: f64,
    /// Epoch-relative instant the first decode round ran.
    pub decode_start_s: f64,
    /// Epoch-relative completion (or teardown) mark.
    pub finished_s: f64,
    /// Epoch-relative *delivery* instant of each sampled token (same
    /// length as `tokens`): stamped when the token is pushed to the
    /// consumer, not when the sampler picked it. The first entry
    /// against `admitted_s` gives time-to-first-token. Tokens delivered
    /// in one event (a speculative verify's accepted run) share an
    /// instant.
    pub token_marks_s: Vec<f64>,
    /// Epoch-relative instant of each delivery *event* (one entry per
    /// sink call; a speculative verify delivers its whole accepted run
    /// as one event). Time-between-tokens gaps are measured over these,
    /// so a k+1-token burst cannot deflate the percentiles with ~0
    /// intra-burst gaps.
    pub delivery_marks_s: Vec<f64>,
    /// How the request ended. Cancelled/expired logs keep the tokens
    /// delivered before teardown.
    pub reason: FinishReason,
    /// Speculative decoding: batched verify passes this request ran
    /// (0 with speculation off or when no draft ever matched).
    pub verify_calls: usize,
    /// Drafted tokens proposed across all verify passes.
    pub draft_tokens: usize,
    /// Drafted tokens accepted (their cached KV survived verification).
    pub draft_accepted: usize,
}

impl SessionLog {
    /// Enqueue → first *delivered* token (queue time included); `None`
    /// when the request delivered no tokens.
    pub fn ttft_s(&self) -> Option<f64> {
        self.token_marks_s
            .first()
            .map(|&t| self.queue_s + (t - self.admitted_s))
    }

    /// Gaps between successive delivery events (empty below two
    /// events). A speculative verify delivers its accepted run as one
    /// event, so these measure consumer-visible latency — the sampler's
    /// internal per-token instants within a burst carry no gap.
    pub fn tbt_gaps_s(&self) -> Vec<f64> {
        self.delivery_marks_s.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// Tokens emitted per verify pass (accepted drafts plus the pass's
    /// own always-emitted token — the bonus on full acceptance, the
    /// sampler's choice on mismatch). `None` without any verify pass;
    /// 1.0 means speculation never beat vanilla decode.
    pub fn accepted_tokens_per_verify(&self) -> Option<f64> {
        if self.verify_calls == 0 {
            None
        } else {
            Some((self.draft_accepted + self.verify_calls) as f64 / self.verify_calls as f64)
        }
    }

    /// Fraction of drafted tokens the verifier accepted (`None` when
    /// nothing was ever drafted).
    pub fn draft_accept_rate(&self) -> Option<f64> {
        if self.draft_tokens == 0 {
            None
        } else {
            Some(self.draft_accepted as f64 / self.draft_tokens as f64)
        }
    }
}

/// Token counts of one settled scheduler round: how many live decode
/// tokens it carried and how many resumable prefill-chunk tokens it
/// spent the remaining budget on.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTokens {
    /// Live decode tokens the round carried.
    pub decode_tokens: usize,
    /// Resumable prefill-chunk tokens the round spent budget on.
    pub prefill_tokens: usize,
}

/// Aggregate round accounting for one batcher (merged across workers by
/// the serving layer): how token-budgeted rounds actually composed.
#[derive(Clone, Debug, Default)]
pub struct RoundStats {
    /// Rounds that processed at least one token.
    pub rounds: usize,
    /// Rounds that mixed live decode tokens with prefill chunks.
    pub mixed_rounds: usize,
    /// Rounds that carried at least one prefill-chunk token.
    pub prefill_rounds: usize,
    /// Decode tokens summed over all rounds.
    pub decode_tokens: usize,
    /// Prompt tokens executed as in-round resumable chunks (0 on the
    /// phase-segregated path, which prefills at admission).
    pub chunked_prefill_tokens: usize,
    /// Largest prefill share any single round carried, bounded by the
    /// token budget (several admitted prompts may each contribute a
    /// chunk to one round).
    pub max_prefill_tokens_round: usize,
    /// Largest prefill share of any round that also carried live decode
    /// tokens — the worst-case decode delay in tokens; with one prompt
    /// streaming it is bounded by the prefill chunk size (the fairness
    /// guarantee).
    pub max_prefill_tokens_decode_round: usize,
    /// Rounds after which the adaptive budget controller observed a
    /// modeled balance and stepped (0 with adaptive budgeting off or on
    /// a functional backend, which feeds no balance).
    pub adaptive_rounds: usize,
    /// Smallest per-round token budget the adaptive controller settled
    /// on (0 when `adaptive_rounds == 0`).
    pub budget_lo: usize,
    /// Largest per-round token budget the adaptive controller settled
    /// on (0 when `adaptive_rounds == 0`).
    pub budget_hi: usize,
}

impl RoundStats {
    /// Fold another worker's round accounting into this one.
    pub fn merge(&mut self, other: &RoundStats) {
        self.rounds += other.rounds;
        self.mixed_rounds += other.mixed_rounds;
        self.prefill_rounds += other.prefill_rounds;
        self.decode_tokens += other.decode_tokens;
        self.chunked_prefill_tokens += other.chunked_prefill_tokens;
        self.max_prefill_tokens_round =
            self.max_prefill_tokens_round.max(other.max_prefill_tokens_round);
        self.max_prefill_tokens_decode_round = self
            .max_prefill_tokens_decode_round
            .max(other.max_prefill_tokens_decode_round);
        if other.adaptive_rounds > 0 {
            if self.adaptive_rounds == 0 {
                self.budget_lo = other.budget_lo;
                self.budget_hi = other.budget_hi;
            } else {
                self.budget_lo = self.budget_lo.min(other.budget_lo);
                self.budget_hi = self.budget_hi.max(other.budget_hi);
            }
            self.adaptive_rounds += other.adaptive_rounds;
        }
    }

    /// Mean prefill tokens per round over rounds that carried any.
    pub fn prefill_tokens_per_round(&self) -> f64 {
        if self.prefill_rounds == 0 {
            0.0
        } else {
            self.chunked_prefill_tokens as f64 / self.prefill_rounds as f64
        }
    }
}

/// Outcome of a successful [`ContinuousBatcher::admit`] call.
#[derive(Debug)]
pub enum Admitted {
    /// Admitted into a slot; rounds will drive it. On the
    /// phase-segregated path its prefill already ran; under a token
    /// budget the prompt streams in as in-round chunks instead.
    Active,
    /// Degenerate `n_out == 0` request: finished at admission
    /// (phase-segregated path only — under a token budget it retires
    /// from the round that completes its prefill).
    Finished(SessionLog),
    /// No free slot, or the page budget is committed to live sequences.
    /// The request is handed back untouched — retry after decode rounds
    /// retire sequences and release their pages.
    Deferred(Request),
}

/// Admission failure: the request itself is unservable on this engine.
#[derive(Clone, Debug)]
pub enum AdmitError {
    /// Worst-case footprint exceeds the whole page pool or the context
    /// window — no amount of waiting can admit it.
    TooLarge {
        id: usize,
        need_tokens: usize,
        need_pages: usize,
        pool_pages: usize,
        max_seq: usize,
    },
    /// The engine's cache failed during prefill (unreachable while
    /// admission commits worst-case pages, kept for defense in depth).
    Cache { id: usize, err: CacheError },
    /// Defensive stall guard: the request would defer while the engine
    /// is idle, so no live flight can ever free the slot or pages it is
    /// waiting for and retrying can never succeed. Unreachable through
    /// this batcher alone (an idle engine has every page free and
    /// `TooLarge` gates pool-exceeding demand), kept typed so a
    /// violated invariant surfaces as an error completion instead of a
    /// worker-killing panic.
    Stalled { id: usize, need_pages: usize, free_pages: usize },
}

impl AdmitError {
    /// The id of the request that failed admission.
    pub fn id(&self) -> usize {
        match *self {
            AdmitError::TooLarge { id, .. }
            | AdmitError::Cache { id, .. }
            | AdmitError::Stalled { id, .. } => id,
        }
    }
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            AdmitError::TooLarge { id, need_tokens, need_pages, pool_pages, max_seq } => write!(
                f,
                "request {id} can never be admitted: needs {need_tokens} cached tokens \
                 ({need_pages} pages) but the pool has {pool_pages} pages and max_seq \
                 is {max_seq}"
            ),
            AdmitError::Cache { id, ref err } => {
                write!(f, "request {id} failed during prefill: {err}")
            }
            AdmitError::Stalled { id, need_pages, free_pages } => write!(
                f,
                "request {id} deferred on an idle engine ({need_pages} pages wanted, \
                 {free_pages} free): nothing can progress"
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Where an in-flight request is in its lifecycle.
enum FlightState {
    /// Admitted under a token budget but the prompt is not fully cached:
    /// prefill advances chunk-by-chunk across rounds.
    Prefilling(PrefillCursor),
    /// Prompt fully cached; `logits` holds the next sampling input.
    Decoding,
}

/// One in-flight request: its session, latest logits, and timing.
struct InFlight {
    req: Request,
    session: Session,
    state: FlightState,
    logits: Vec<f32>,
    tokens: Vec<u32>,
    /// Rolling `prompt + tokens` history for the drafter — maintained
    /// incrementally so `draft_for` never rebuilds an O(prompt) Vec per
    /// decode step.
    history: Vec<u32>,
    /// Epoch-relative delivery instant of each sampled token.
    token_marks_s: Vec<f64>,
    /// Epoch-relative instant of each delivery event (one per sink
    /// call; a verify's accepted run is one event).
    delivery_marks_s: Vec<f64>,
    /// Epoch-relative instant the request's deadline expires (enqueue
    /// instant + `Request::deadline_s`), checked by `reap`.
    deadline_epoch_s: Option<f64>,
    /// The last sampled token has not been forwarded yet (its logits
    /// are pending): set after every speculative verify, so the next
    /// round forwards it instead of sampling again — stateful samplers
    /// advance exactly once per token. Always false with speculation
    /// off.
    pending_forward: bool,
    /// Speculation counters, moved into the [`SessionLog`] at finish.
    verify_calls: usize,
    draft_tokens: usize,
    draft_accepted: usize,
    /// Fresh worst-case pages committed against the pool (worst case
    /// minus aliased prefix pages; the aliased pages enter the distinct
    /// demand via the batcher's shared-page union).
    fresh_pages: usize,
    /// Cached prefix pages this request aliased at admission.
    aliased: Vec<u32>,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    admitted_s: f64,
    decode_start_s: f64,
}

impl InFlight {
    /// Split into the session (returned to the engine's slot pool) and
    /// the request's lifecycle log.
    fn finish(self, finished_s: f64, reason: FinishReason) -> (Session, SessionLog) {
        let InFlight {
            req,
            session,
            state: _,
            logits: _,
            tokens,
            history: _,
            token_marks_s,
            delivery_marks_s,
            deadline_epoch_s: _,
            pending_forward: _,
            verify_calls,
            draft_tokens,
            draft_accepted,
            fresh_pages: _,
            aliased: _,
            queue_s,
            prefill_s,
            decode_s,
            admitted_s,
            decode_start_s,
        } = self;
        let log = SessionLog {
            id: req.id,
            n_prefill: req.prompt.len(),
            tenant: req.tenant,
            tokens,
            queue_s,
            prefill_s,
            decode_s,
            admitted_s,
            decode_start_s,
            finished_s,
            token_marks_s,
            delivery_marks_s,
            reason,
            verify_calls,
            draft_tokens,
            draft_accepted,
        };
        (session, log)
    }
}

/// Iteration-level scheduler for one worker: admit → prefill as ubatches
/// → interleaved decode rounds, over the engine's session slots.
pub struct ContinuousBatcher {
    engine: Engine,
    ubatch: usize,
    epoch: Instant,
    /// Per-round token cap for the mixed iteration scheduler. `None`
    /// keeps the phase-segregated schedule (whole prefill at admission).
    token_budget: Option<usize>,
    /// Closed-loop budget controller: when set, every settled round's
    /// modeled LOAD/EXEC balance steps `token_budget` inside the
    /// controller's `[min, max]` band.
    adaptive: Option<AdaptiveBudget>,
    /// The budget each adaptive step settled on, in round order (empty
    /// with adaptive budgeting off or on a functional backend).
    budget_trace: Vec<usize>,
    /// Largest resumable prefill chunk one round may carry per request
    /// (further capped by the remaining budget).
    prefill_chunk: usize,
    /// Queue-depth-aware chunk sizing: split each round's leftover
    /// budget evenly across the flights still prefilling (never above
    /// `prefill_chunk`), so a deep prefill queue lowers worst-case TTFT
    /// instead of serving cursors strictly in admission order.
    adaptive_chunk: bool,
    /// Drafted tokens verified per live sequence per round (0 = vanilla
    /// decode, one forward pass per token).
    speculate: usize,
    /// Draft proposer for the speculative path.
    drafter: NgramDrafter,
    /// Streaming delivery sink: every sampled token is pushed here the
    /// moment it becomes consumer-visible. `None` = report-at-finish
    /// only (marks are still stamped at the same delivery points).
    sink: Option<DeliverySink>,
    /// Latched when the sink reports a gone consumer; `reap` then
    /// cancels every in-flight request.
    delivery_closed: bool,
    /// Token counts of every settled round, in order.
    rounds: Vec<RoundTokens>,
    active: Vec<InFlight>,
    /// Pages committed to live sequences' worst cases (≥ pages actually
    /// allocated, so decode-time growth can never hit an empty pool):
    /// the exact distinct demand — every live request's fresh pages plus
    /// one unit per distinct aliased page no live request self-allocated.
    /// Recomputed from live state on every admit/finish.
    committed_pages: usize,
    /// Admissions that aliased at least one cached page, and the prompt
    /// tokens those admissions skipped.
    prefix_hits: usize,
    prefix_hit_tokens: usize,
}

impl ContinuousBatcher {
    /// `epoch` is the serving run's start instant (shared across workers
    /// so every `SessionLog` sits on one timeline).
    pub fn new(engine: Engine, ubatch: usize, epoch: Instant) -> ContinuousBatcher {
        assert!(ubatch >= 1);
        ContinuousBatcher {
            engine,
            ubatch,
            epoch,
            token_budget: None,
            adaptive: None,
            budget_trace: Vec::new(),
            prefill_chunk: ubatch,
            adaptive_chunk: false,
            speculate: 0,
            drafter: DrafterSpec::default().build(),
            sink: None,
            delivery_closed: false,
            rounds: Vec::new(),
            active: Vec::new(),
            committed_pages: 0,
            prefix_hits: 0,
            prefix_hit_tokens: 0,
        }
    }

    /// Switch to token-budget iteration scheduling: every round carries
    /// at most `budget` tokens — all live decode tokens first, then
    /// resumable prefill chunks — and admission no longer runs prefill
    /// inline (see the module docs).
    pub fn with_token_budget(mut self, budget: usize) -> ContinuousBatcher {
        assert!(budget >= 1, "token budget must be at least 1");
        self.token_budget = Some(budget);
        self
    }

    /// Cap each request's per-round prefill chunk (default: the ubatch
    /// size). Only meaningful with a token budget set.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> ContinuousBatcher {
        assert!(chunk >= 1, "prefill chunk must be at least 1");
        self.prefill_chunk = chunk;
        self
    }

    /// Close the budget control loop: after every settled round the
    /// controller reads the backend's modeled LOAD/EXEC balance and
    /// steps the per-round token budget inside `spec`'s `[min, max]`
    /// band (see [`AdaptiveBudget`]). Implies token-budget scheduling:
    /// the starting budget is the configured `with_token_budget` value
    /// clamped into the band, or `spec.max` when none was set. The
    /// decode-starvation guarantee is untouched — every live decode
    /// token is budget-exempt regardless of where the controller walks.
    pub fn with_adaptive_budget(mut self, spec: AdaptiveBudget) -> ContinuousBatcher {
        let start = self.token_budget.unwrap_or(spec.max).clamp(spec.min, spec.max);
        self.token_budget = Some(start);
        self.adaptive = Some(spec);
        self
    }

    /// Enable queue-depth-aware prefill chunk sizing: each round splits
    /// its leftover budget evenly across the flights still prefilling
    /// (capped by `with_prefill_chunk`), instead of feeding cursors the
    /// full chunk strictly in admission order. With a deep prefill
    /// queue this spreads every round across more waiting prompts —
    /// lower worst-case TTFT at identical tokens. Only meaningful with
    /// a token budget set.
    pub fn with_adaptive_chunk(mut self, enabled: bool) -> ContinuousBatcher {
        self.adaptive_chunk = enabled;
        self
    }

    /// Enable speculative decoding: every decode round drafts up to `k`
    /// tokens per live sequence with `drafter` and verifies the draft
    /// in one batched ubatch. Output is bit-identical to vanilla decode
    /// (the verifier replays the session's own sampler over the verify
    /// logits in vanilla order); accepted tokens amortize the round's
    /// streamed weight bytes. `k == 0` keeps vanilla decode.
    pub fn with_speculation(mut self, k: usize, drafter: DrafterSpec) -> ContinuousBatcher {
        self.speculate = k;
        self.drafter = drafter.build();
        self
    }

    /// Attach a streaming delivery sink: every sampled token is pushed
    /// as a [`TokenEvent`] the moment it becomes consumer-visible, and
    /// latency marks are stamped at that push. A sink returning `false`
    /// latches delivery-closed and cancels every in-flight request at
    /// the next round boundary.
    pub fn with_delivery(mut self, sink: DeliverySink) -> ContinuousBatcher {
        self.sink = Some(sink);
        self
    }

    /// True once an attached delivery sink reported a gone consumer.
    pub fn delivery_closed(&self) -> bool {
        self.delivery_closed
    }

    /// The configured draft length (0 = speculation off).
    pub fn speculate(&self) -> usize {
        self.speculate
    }

    /// The current per-round token budget (`None` = phase-segregated).
    /// Under [`ContinuousBatcher::with_adaptive_budget`] this is the
    /// value the controller last settled on.
    pub fn token_budget(&self) -> Option<usize> {
        self.token_budget
    }

    /// The adaptive budget controller, if one is closed over this
    /// batcher.
    pub fn adaptive_budget(&self) -> Option<AdaptiveBudget> {
        self.adaptive
    }

    /// The budget each adaptive controller step settled on, in round
    /// order (empty with adaptive budgeting off, or when the backend
    /// never fed a modeled balance).
    pub fn budget_trace(&self) -> &[usize] {
        &self.budget_trace
    }

    /// Token counts of every settled round, in order.
    pub fn rounds(&self) -> &[RoundTokens] {
        &self.rounds
    }

    /// Aggregate round composition (token-budget scheduling telemetry).
    pub fn round_stats(&self) -> RoundStats {
        let mut s = RoundStats::default();
        for r in &self.rounds {
            s.rounds += 1;
            s.decode_tokens += r.decode_tokens;
            s.chunked_prefill_tokens += r.prefill_tokens;
            if r.prefill_tokens > 0 {
                s.prefill_rounds += 1;
            }
            if r.decode_tokens > 0 && r.prefill_tokens > 0 {
                s.mixed_rounds += 1;
                s.max_prefill_tokens_decode_round =
                    s.max_prefill_tokens_decode_round.max(r.prefill_tokens);
            }
            s.max_prefill_tokens_round = s.max_prefill_tokens_round.max(r.prefill_tokens);
        }
        if !self.budget_trace.is_empty() {
            s.adaptive_rounds = self.budget_trace.len();
            s.budget_lo = *self.budget_trace.iter().min().expect("nonempty trace");
            s.budget_hi = *self.budget_trace.iter().max().expect("nonempty trace");
        }
        s
    }

    /// Free session slots (how many more requests can be admitted, slot
    /// count permitting — admission additionally gates on the page
    /// budget; see [`ContinuousBatcher::admit`]).
    pub fn capacity(&self) -> usize {
        self.engine.free_sessions()
    }

    /// Number of sessions currently admitted and live.
    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// The underlying engine (slot and cache introspection).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// KV pages committed to live sequences' worst cases (fresh pages
    /// plus distinct pinned shared pages).
    pub fn committed_pages(&self) -> usize {
        self.committed_pages
    }

    /// Recompute the live set's exact distinct page demand from scratch —
    /// the quantity [`ContinuousBatcher::committed_pages`] caches between
    /// live-set changes. Exposed for the `analysis` auditor's
    /// budget-conservation proof (`audit/budget-conservation`): a cached
    /// value drifting from this recomputation is exactly the bug class
    /// the audit exists to catch.
    pub fn recomputed_committed_pages(&self) -> usize {
        self.distinct_demand(None)
    }

    /// Sharing/eviction counters: the engine cache's CoW/evict/swap
    /// tallies plus this batcher's admission-level prefix-hit counts.
    pub fn reuse_stats(&self) -> KvReuseStats {
        let mut s = self.engine.cache.reuse_stats().clone();
        s.prefix_hits = self.prefix_hits;
        s.prefix_hit_tokens = self.prefix_hit_tokens;
        s
    }

    /// Cached tokens a request needs at its longest: the prompt plus
    /// every decoded token except the last (which is sampled without a
    /// further forward pass).
    fn request_tokens(req: &Request) -> usize {
        req.prompt.len() + req.n_out.saturating_sub(1)
    }

    /// What admitting `req` would cost the pool *right now*, prefix
    /// discount included: worst-case pages minus the currently cached
    /// page-aligned prefix. The shortest-job-first policy sorts the scan
    /// window by this.
    pub fn effective_cost_pages(&self, req: &Request) -> usize {
        let need = self.engine.pages_needed(Self::request_tokens(req));
        let (cached_tokens, _, _) = self.engine.peek_prefix(&req.prompt);
        need.saturating_sub(self.engine.pages_needed(cached_tokens))
    }

    /// Exact distinct worst-case page demand of the live set, with
    /// `extra` standing in for a candidate admission `(fresh pages,
    /// aliased pages)` not yet in `active`: Σ fresh + |aliased pages no
    /// live request self-allocated|. Shared pages count exactly once —
    /// an aliased page whose allocator is still live is already inside
    /// that allocator's fresh term; once the allocator finishes, the
    /// union term picks the page up.
    fn distinct_demand(&self, extra: Option<(usize, &[u32])>) -> usize {
        let mut total = 0usize;
        let mut self_alloc: Vec<u32> = Vec::new();
        let mut aliased: Vec<u32> = Vec::new();
        let mut visit = |fresh: usize, alias: &[u32], table: &[u32]| {
            total += fresh;
            // Pages beyond the aliased prefix were allocated by this
            // request itself (prompt tail + decode growth).
            self_alloc.extend_from_slice(&table[alias.len().min(table.len())..]);
            aliased.extend_from_slice(alias);
        };
        for f in &self.active {
            visit(f.fresh_pages, &f.aliased, self.engine.cache.slot_pages(f.session.slot()));
        }
        if let Some((fresh, alias)) = extra {
            // The candidate's table holds exactly its aliased pages.
            visit(fresh, alias, alias);
        }
        aliased.sort_unstable();
        aliased.dedup();
        total + aliased.iter().filter(|p| !self_alloc.contains(p)).count()
    }

    /// Refresh the cached commitment after the live set changed.
    fn recompute_committed(&mut self) {
        self.committed_pages = self.distinct_demand(None);
    }

    /// Admit one request, skipping the prompt span served by the prefix
    /// cache. On the phase-segregated path (no token budget) its whole
    /// prefill runs here as ubatch chunks; under a token budget the
    /// request enters the prefilling state and its prompt streams in as
    /// bounded in-round chunks instead.
    ///
    /// Admission is page-budget-gated on the live set's exact distinct
    /// demand (the `distinct_demand` invariant):
    /// the request's worst case (`prompt + n_out − 1` cached tokens)
    /// minus its aliased prefix pages, with each distinct shared page
    /// counted once across the whole live set — so a mix of live
    /// sequences can never run the pool dry mid-decode, and unpinned
    /// cached pages don't count at all (the cache evicts them on
    /// demand). Not enough budget or no free slot right now returns
    /// [`Admitted::Deferred`] with the request handed back; a request
    /// whose worst case exceeds the whole pool (or the context window)
    /// returns [`AdmitError::TooLarge`].
    pub fn admit(
        &mut self,
        req: Request,
        sampler: Sampler,
        queue_s: f64,
        exec: &mut dyn KernelExec,
    ) -> Result<Admitted, AdmitError> {
        let need_tokens = Self::request_tokens(&req);
        let need_pages = self.engine.pages_needed(need_tokens);
        let pool_pages = self.engine.total_pages();
        let max_seq = self.engine.cfg().max_seq_len;
        if need_tokens > max_seq || need_pages > pool_pages {
            return Err(AdmitError::TooLarge {
                id: req.id,
                need_tokens,
                need_pages,
                pool_pages,
                max_seq,
            });
        }
        if self.engine.free_sessions() == 0 {
            if self.active.is_empty() {
                // Nothing live can ever return a slot: surface the
                // stall as a typed error instead of an endless retry.
                return Err(AdmitError::Stalled {
                    id: req.id,
                    need_pages,
                    free_pages: self.engine.free_pages(),
                });
            }
            return Ok(Admitted::Deferred(req));
        }
        // Invariant: `free_sessions() > 0` was checked above and nothing
        // between the check and here opens a session, so this cannot
        // fail; a `None` would mean the engine lost track of its own
        // slot accounting (a bug, not a recoverable condition).
        let session = self
            .engine
            .open_session(sampler)
            .expect("free slot checked above");
        // Alias the cached prompt prefix (swapping evicted pages back in)
        // *before* gating, so the commitment is exact for what this
        // request can still demand. On deferral the aliases are undone;
        // any swap-ins stay cached, so the retry is cheaper.
        let adopted = self.engine.adopt_prefix(&session, &req.prompt, exec);
        let fresh_pages = need_pages - adopted.pages.len();
        let demand = self.distinct_demand(Some((fresh_pages, &adopted.pages)));
        if demand > pool_pages {
            self.engine.close_session(session);
            if self.active.is_empty() {
                // An idle engine's distinct demand is the request's own
                // worst case, already gated by TooLarge — deferring
                // here could never resolve (see `AdmitError::Stalled`).
                return Err(AdmitError::Stalled {
                    id: req.id,
                    need_pages,
                    free_pages: self.engine.free_pages(),
                });
            }
            return Ok(Admitted::Deferred(req));
        }
        self.committed_pages = demand;
        let admitted_s = self.epoch.elapsed().as_secs_f64();
        // The deadline clock started at enqueue: `admitted_s − queue_s`
        // recovers the epoch-relative enqueue instant.
        let deadline_epoch_s = req.deadline_s.map(|d| admitted_s - queue_s + d);
        let mut history = Vec::with_capacity(req.prompt.len() + req.n_out);
        history.extend_from_slice(&req.prompt);
        if self.token_budget.is_some() {
            // Token-budget path: the prompt prefills chunk-by-chunk in
            // later rounds (interleaved with live decodes) instead of
            // monopolizing the engine here. Its worst-case pages are
            // already committed, so in-round chunk reservations cannot
            // fail.
            if adopted.tokens > 0 {
                self.prefix_hits += 1;
                self.prefix_hit_tokens += adopted.tokens;
            }
            let cursor = PrefillCursor::with_adopted(req.prompt.clone(), adopted.tokens);
            self.active.push(InFlight {
                req,
                session,
                state: FlightState::Prefilling(cursor),
                logits: Vec::new(),
                tokens: Vec::new(),
                history,
                token_marks_s: Vec::new(),
                delivery_marks_s: Vec::new(),
                deadline_epoch_s,
                pending_forward: false,
                verify_calls: 0,
                draft_tokens: 0,
                draft_accepted: 0,
                fresh_pages,
                aliased: adopted.pages,
                queue_s,
                prefill_s: 0.0,
                decode_s: 0.0,
                admitted_s,
                decode_start_s: admitted_s,
            });
            return Ok(Admitted::Active);
        }
        let tp0 = Instant::now();
        let logits = match self.engine.try_prefill_session(
            &session,
            &req.prompt[adopted.tokens..],
            self.ubatch,
            exec,
        ) {
            Ok(logits) => logits,
            Err(err) => {
                let id = req.id;
                self.engine.close_session(session);
                self.recompute_committed();
                return Err(AdmitError::Cache { id, err });
            }
        };
        // Publish the committed prompt pages for future sharing.
        self.engine.register_prefix(&session, &req.prompt);
        if adopted.tokens > 0 {
            self.prefix_hits += 1;
            self.prefix_hit_tokens += adopted.tokens;
        }
        let prefill_s = tp0.elapsed().as_secs_f64();
        let inflight = InFlight {
            req,
            session,
            state: FlightState::Decoding,
            logits,
            tokens: Vec::new(),
            history,
            token_marks_s: Vec::new(),
            delivery_marks_s: Vec::new(),
            deadline_epoch_s,
            pending_forward: false,
            verify_calls: 0,
            draft_tokens: 0,
            draft_accepted: 0,
            fresh_pages,
            aliased: adopted.pages,
            queue_s,
            prefill_s,
            decode_s: 0.0,
            admitted_s,
            decode_start_s: admitted_s + prefill_s,
        };
        if inflight.req.n_out == 0 {
            let finished_s = self.epoch.elapsed().as_secs_f64();
            let (session, mut log) = inflight.finish(finished_s, FinishReason::Completed);
            self.engine.close_session(session);
            self.recompute_committed();
            // A 0-output request never decodes; pin its decode mark to
            // its finish time so interval arithmetic stays well-formed.
            log.decode_start_s = log.finished_s;
            return Ok(Admitted::Finished(log));
        }
        self.active.push(inflight);
        Ok(Admitted::Active)
    }

    /// Draft a speculative continuation for live flight `i`: at most
    /// `speculate` tokens, further capped by the request's remaining
    /// output room (sampling a verify emits up to k+1 tokens and caches
    /// 1+k positions, so k ≤ room−1 keeps both inside the
    /// admission-committed worst case of `prompt + n_out − 1` cached
    /// tokens — verify can never reserve a page admission didn't pay
    /// for) and by `budget_room`. Proposed by the n-gram drafter over
    /// prompt + generated history, with the prefix cache's committed
    /// spans as fallback corpus when enabled. Empty with speculation
    /// off or when no gram matches.
    fn draft_for(&self, i: usize, budget_room: usize) -> Vec<u32> {
        if self.speculate == 0 {
            return Vec::new();
        }
        let f = &self.active[i];
        let room = f.req.n_out - f.tokens.len();
        let k = self.speculate.min(room.saturating_sub(1)).min(budget_room);
        if k == 0 {
            return Vec::new();
        }
        // The rolling history is maintained at every token push, so no
        // O(prompt) rebuild happens per decode step.
        debug_assert_eq!(f.history.len(), f.req.prompt.len() + f.tokens.len());
        let corpus = self.engine.cache.prefix_token_spans();
        self.drafter.draft(&f.history, &corpus, k)
    }

    /// Deliver the last `n_new` sampled tokens of flight `f` as **one**
    /// delivery event: stamp the marks *now* — the instant the consumer
    /// can actually observe the tokens, not when the sampler picked
    /// them — and push them into the sink, if any. `done` flags the
    /// final token. A sink refusing an event latches `closed`.
    fn deliver(
        epoch: Instant,
        sink: &mut Option<DeliverySink>,
        closed: &mut bool,
        f: &mut InFlight,
        n_new: usize,
        done: bool,
    ) {
        debug_assert!(n_new >= 1 && n_new <= f.tokens.len());
        let mark_s = epoch.elapsed().as_secs_f64();
        f.token_marks_s.resize(f.tokens.len(), mark_s);
        f.delivery_marks_s.push(mark_s);
        if let Some(sink) = sink.as_mut() {
            let start = f.tokens.len() - n_new;
            for (j, &token) in f.tokens[start..].iter().enumerate() {
                let last = start + j + 1 == f.tokens.len();
                let event = TokenEvent { request_id: f.req.id, token, mark_s, done: done && last };
                if !sink(event) {
                    *closed = true;
                    break;
                }
            }
        }
    }

    /// Sweep cancelled and deadline-expired flights (every flight once
    /// the delivery sink has closed), tearing each one down through the
    /// refcounted release path: `close_session` resets the slot's page
    /// table — mid-[`PrefillCursor`] and pending-verify states included
    /// — so CoW/shared pages drop one reference, pages pinned by the
    /// prefix-cache index stay adoptable, and everything else returns
    /// to the pool. The freed slot and page budget are available to the
    /// very next admission/prefill pass — the same scheduling round.
    ///
    /// Runs automatically at the start of every
    /// [`ContinuousBatcher::decode_round`]; the serving loop also calls
    /// it right before admission. Returns the logs of reaped flights
    /// (tokens delivered before teardown preserved, `reason` set).
    pub fn reap(&mut self) -> Vec<SessionLog> {
        let mut reaped = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let now_s = self.epoch.elapsed().as_secs_f64();
            let f = &self.active[i];
            let reason = if self.delivery_closed || f.req.is_cancelled() {
                Some(FinishReason::Cancelled)
            } else if f.deadline_epoch_s.map_or(false, |d| now_s >= d) {
                Some(FinishReason::DeadlineExpired)
            } else {
                None
            };
            let Some(reason) = reason else {
                i += 1;
                continue;
            };
            let f = self.active.remove(i);
            let (session, log) = f.finish(now_s, reason);
            self.engine.close_session(session);
            reaped.push(log);
        }
        if !reaped.is_empty() {
            self.recompute_committed();
        }
        reaped
    }

    /// Verify `next` plus `draft` for flight `i` in one batched ubatch,
    /// replaying the session's sampler over the per-position logits
    /// exactly as vanilla decode would — the sampler sees the same
    /// logits in the same order whether the draft is right or wrong, so
    /// output is bit-identical by construction. Accepted tokens keep
    /// their cached KV; the first mismatch truncates the rejected tail
    /// through the paged pool (refcount/CoW-safe). The last sampled
    /// token (bonus on full acceptance, the sampler's own pick on
    /// mismatch) has no cached entry yet and is left pending its
    /// forward pass. Returns whether the request finished.
    fn verify_draft(
        &mut self,
        i: usize,
        next: u32,
        draft: &[u32],
        exec: &mut dyn KernelExec,
    ) -> bool {
        let mut ubatch = Vec::with_capacity(1 + draft.len());
        ubatch.push(next);
        ubatch.extend_from_slice(draft);
        let f = &mut self.active[i];
        let base_len = self.engine.session_pos(&f.session);
        // Invariant: admission committed this flight's worst-case page
        // demand (`distinct_demand`), and a verify never extends the
        // sequence past `prompt + n_out − 1` cached tokens, so the cache
        // reservation cannot fail here. `audit/budget-conservation`
        // cross-checks the commitment each round under `--audit`.
        let rows = self
            .engine
            .try_verify_session(&f.session, &ubatch, exec)
            .expect("verify pages committed at admission");
        f.verify_calls += 1;
        f.draft_tokens += draft.len();
        let mut accepted = 0usize;
        let mut emitted = 0usize;
        let mut done = false;
        for (j, row) in rows.iter().enumerate() {
            let sampled = f.session.sampler.sample(row);
            f.tokens.push(sampled);
            f.history.push(sampled);
            emitted += 1;
            let matched = j < draft.len() && sampled == draft[j];
            if matched {
                accepted += 1;
            }
            if f.tokens.len() == f.req.n_out {
                done = true;
                break;
            }
            if !matched {
                break;
            }
        }
        f.draft_accepted += accepted;
        // The whole accepted run becomes consumer-visible here, as one
        // delivery event: marks stamped at delivery, so the burst's
        // intra-verify instants cannot deflate the TBT percentiles.
        Self::deliver(self.epoch, &mut self.sink, &mut self.delivery_closed, f, emitted, done);
        if !done {
            // Roll back rejected-draft KV entries; the pending token's
            // position was never cached, so the valid length is the
            // base plus `next` plus the accepted prefix.
            if accepted < draft.len() {
                self.engine.truncate_session(&f.session, base_len + 1 + accepted);
            }
            f.pending_forward = true;
        }
        done
    }

    /// One token-budgeted round, in admission order; requests that reach
    /// their `n_out` are retired and returned, as are flights reaped by
    /// the round-opening cancellation/deadline sweep (see
    /// [`ContinuousBatcher::reap`] — their freed budget is spent by this
    /// very round). Each completed request samples exactly `n_out`
    /// tokens over its lifetime (the final sampled token needs no
    /// further forward pass).
    ///
    /// The round runs two passes. First the *decode pass*: one decode
    /// step for **every** live decoding request — the decode-starvation
    /// guarantee; live decodes are never displaced by prefill work, even
    /// when they alone exceed the budget. With speculation on, each
    /// decode step may extend into a drafted verify (up to `speculate`
    /// extra budgeted tokens, see [`ContinuousBatcher::with_speculation`])
    /// that emits several tokens from one batched pass while staying
    /// bit-identical to vanilla decode. Then the *prefill pass*: the
    /// remaining budget (`token_budget − decode tokens`) feeds resumable
    /// prefill chunks (at most `prefill_chunk` tokens per request) to
    /// admitted-but-unprefilled slots; a request whose cursor completes
    /// registers its prompt pages for prefix sharing and decodes from
    /// the next round on. Without a token budget the prefill pass is
    /// idle (admission prefills inline) and this is exactly the classic
    /// phase-segregated decode round.
    pub fn decode_round(&mut self, exec: &mut dyn KernelExec) -> Vec<SessionLog> {
        // Tear down cancelled/expired flights first: the budget they
        // would have consumed flows to the surviving requests' decode
        // and prefill passes below — the same round spends it.
        let mut finished = self.reap();
        let budget = self.token_budget.unwrap_or(usize::MAX);
        let mut decoded = 0usize;
        let mut i = 0;
        while i < self.active.len() {
            if matches!(self.active[i].state, FlightState::Prefilling(_)) {
                i += 1;
                continue;
            }
            let td0 = Instant::now();
            let f = &mut self.active[i];
            if f.tokens.is_empty() {
                f.decode_start_s = self.epoch.elapsed().as_secs_f64();
            }
            if f.pending_forward {
                // A speculative verify left its last sampled token
                // unforwarded (`f.logits` is stale until it runs): this
                // round forwards it instead of sampling again. The
                // token itself was already delivered by the verify.
                f.pending_forward = false;
            } else {
                let next = f.session.sampler.sample(&f.logits);
                f.tokens.push(next);
                f.history.push(next);
                let last = f.tokens.len() == f.req.n_out;
                Self::deliver(self.epoch, &mut self.sink, &mut self.delivery_closed, f, 1, last);
            }
            let mut done = f.tokens.len() == f.req.n_out;
            if done {
                decoded += 1;
            } else {
                // Invariant: a flight only reaches `Decoding` after its
                // prefill (or verify) pushed at least one sampled token,
                // and tokens are never popped — `last()` always exists.
                let next = *f.tokens.last().expect("decoding flight has a sampled token");
                // Drafted tokens are budgeted tokens: the mandatory
                // decode token stays starvation-exempt, the speculative
                // extension spends only what the budget still allows —
                // a k-token verify competes with prefill chunks.
                let draft = self.draft_for(i, budget.saturating_sub(decoded + 1));
                if draft.is_empty() {
                    decoded += 1;
                    let f = &mut self.active[i];
                    // Invariant: same page-commitment argument as
                    // `verify_draft` — one decode token stays inside the
                    // admitted worst case, and `logits=true` guarantees
                    // the engine returns a row.
                    f.logits = self
                        .engine
                        .forward_session(&f.session, next, Phase::Decode, true, exec)
                        .expect("decode produced logits");
                } else {
                    decoded += 1 + draft.len();
                    done = self.verify_draft(i, next, &draft, exec);
                }
            }
            self.active[i].decode_s += td0.elapsed().as_secs_f64();
            if done {
                let f = self.active.remove(i);
                let finished_s = self.epoch.elapsed().as_secs_f64();
                let (session, log) = f.finish(finished_s, FinishReason::Completed);
                self.engine.close_session(session);
                finished.push(log);
            } else {
                i += 1;
            }
        }
        // Prefill pass: spend what the decodes (mandatory tokens plus
        // drafted verify positions) left of the budget on resumable
        // chunks, in admission order. With queue-depth-aware chunk
        // sizing the leftover budget is split evenly across every
        // cursor still waiting (never above `prefill_chunk`), so a deep
        // prefill queue advances many prompts a little per round
        // instead of one prompt a lot.
        let mut spent = decoded;
        let mut prefilled = 0usize;
        let waiting = self
            .active
            .iter()
            .filter(|f| matches!(f.state, FlightState::Prefilling(_)))
            .count();
        let chunk_cap = if self.adaptive_chunk && waiting > 0 {
            let leftover = budget.saturating_sub(spent);
            self.prefill_chunk.min((leftover / waiting).max(1))
        } else {
            self.prefill_chunk
        };
        let mut i = 0;
        while i < self.active.len() && spent < budget {
            if !matches!(self.active[i].state, FlightState::Prefilling(_)) {
                i += 1;
                continue;
            }
            let tp0 = Instant::now();
            let max = chunk_cap.min(budget - spent);
            let f = &mut self.active[i];
            let FlightState::Prefilling(cursor) = &mut f.state else {
                unreachable!("checked above");
            };
            let before = cursor.pos();
            // Invariant: the whole prompt is inside the worst case
            // admission committed, so a resumable chunk can never hit a
            // page reservation failure mid-prefill.
            let logits = self
                .engine
                .prefill_partial(&f.session, cursor, max, exec)
                .expect("chunk pages committed at admission");
            let executed = cursor.pos() - before;
            spent += executed;
            prefilled += executed;
            f.prefill_s += tp0.elapsed().as_secs_f64();
            if let Some(logits) = logits {
                // Prompt fully cached: publish its pages for sharing and
                // decode from the next round on.
                self.engine.register_prefix(&f.session, &f.req.prompt);
                f.logits = logits;
                f.state = FlightState::Decoding;
                if f.req.n_out == 0 {
                    let f = self.active.remove(i);
                    let finished_s = self.epoch.elapsed().as_secs_f64();
                    let (session, mut log) = f.finish(finished_s, FinishReason::Completed);
                    self.engine.close_session(session);
                    // Never decodes; pin the mark (see `admit`).
                    log.decode_start_s = log.finished_s;
                    finished.push(log);
                    continue;
                }
            }
            i += 1;
        }
        if decoded + prefilled > 0 {
            self.rounds.push(RoundTokens {
                decode_tokens: decoded,
                prefill_tokens: prefilled,
            });
            exec.round_boundary();
            // Adaptive budget: steer next round's token budget from the
            // modeled LOAD/EXEC balance the backend just snapshotted.
            // Backends that don't model phase costs return `None`, which
            // freezes the budget at its current value (functional runs
            // keep exact fixed-budget behavior).
            if let Some(spec) = self.adaptive {
                if let Some(bal) = exec.last_round_balance() {
                    let cur = self.token_budget.unwrap_or(spec.max);
                    let next = spec.next_budget(cur, &bal);
                    self.token_budget = Some(next);
                    self.budget_trace.push(next);
                }
            }
        }
        if !finished.is_empty() {
            // One recomputation covers every retirement this round (the
            // admission gate recomputes its own demand, so the cached
            // value is only read between rounds).
            self.recompute_committed();
        }
        finished
    }

    /// Drain every active request to completion (no further admissions).
    pub fn drain(&mut self, exec: &mut dyn KernelExec) -> Vec<SessionLog> {
        let mut out = Vec::new();
        while self.n_active() > 0 {
            out.extend(self.decode_round(exec));
        }
        out
    }
}

/// One point of the Fig 16 sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    /// Lane count this point was simulated at.
    pub lanes: usize,
    /// Modeled end-to-end seconds for the workload.
    pub e2e_s: f64,
    /// Decode throughput at this lane count.
    pub tokens_per_s: f64,
    /// Modeled accelerator EXEC seconds.
    pub exec_s: f64,
    /// Modeled host-side seconds (the scaling bottleneck).
    pub host_s: f64,
    /// Full simulation result behind the headline numbers.
    pub run: WorkloadRun,
}

/// Sweep lane counts for a workload on a device family.
pub fn lane_sweep(
    w: &Workload,
    base: &ImaxDevice,
    lanes: &[usize],
    mode: TransferMode,
) -> Vec<ScalingPoint> {
    lanes
        .iter()
        .map(|&n| {
            let dev = base.clone().with_lanes(n);
            let policy =
                OffloadPolicy::for_workload(&dev, &w.cfg, w.scheme, LmmConfig::new(dev.lmm_kb));
            let run = simulate(w, &dev, &policy, mode);
            let total = run.breakdown.total();
            let e2e = run.breakdown.e2e_seconds();
            ScalingPoint {
                lanes: n,
                e2e_s: e2e,
                tokens_per_s: (w.n_in + w.n_out) as f64 / e2e,
                exec_s: total.exec,
                host_s: total.host,
                run,
            }
        })
        .collect()
}

/// The lane count with the best E2E latency in a sweep.
pub fn best_lanes(points: &[ScalingPoint]) -> usize {
    points
        .iter()
        .min_by(|a, b| a.e2e_s.total_cmp(&b.e2e_s))
        .map(|p| p.lanes)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, QuantScheme};
    use crate::model::engine::NativeExec;
    use crate::model::weights::ModelWeights;

    fn workload() -> Workload {
        Workload {
            cfg: ModelConfig::qwen3_0_6b(),
            scheme: QuantScheme::Q3KS,
            n_in: 32,
            n_out: 16,
        }
    }

    fn tiny_weights() -> ModelWeights {
        ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 11)
    }

    #[test]
    fn batcher_matches_generate() {
        let weights = tiny_weights();
        let prompt = vec![1u32, 5, 9, 2, 7];
        let n_out = 6;

        let mut b = ContinuousBatcher::new(
            Engine::with_slots(weights.clone(), 2),
            3,
            Instant::now(),
        );
        let mut exec = NativeExec;
        let req = Request::new(0, prompt.clone(), n_out);
        assert!(matches!(
            b.admit(req, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 1);
        assert_eq!(b.committed_pages(), 0, "drained batcher holds no budget");

        let mut reference = Engine::new(weights);
        let want = reference.generate(&prompt, n_out, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(logs[0].tokens, want.tokens, "batcher must match generate");
        assert_eq!(logs[0].n_prefill, prompt.len());
        assert!(logs[0].decode_start_s >= logs[0].admitted_s);
        assert!(logs[0].finished_s >= logs[0].decode_start_s);
    }

    #[test]
    fn mid_run_admission_interleaves() {
        // The continuous-batching property, deterministically: a request
        // admitted after another has started decoding finishes its own
        // decode before the earlier request completes.
        let weights = tiny_weights();
        let mut b =
            ContinuousBatcher::new(Engine::with_slots(weights, 2), 32, Instant::now());
        let mut exec = NativeExec;

        let r0 = Request::new(0, vec![1, 2, 3], 8);
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec).unwrap();
        // r0 decodes a few rounds alone…
        for _ in 0..3 {
            assert!(b.decode_round(&mut exec).is_empty());
        }
        // …then r1 arrives mid-run and joins the same engine.
        let r1 = Request::new(1, vec![9, 8], 2);
        b.admit(r1, Sampler::greedy(), 0.0, &mut exec).unwrap();
        assert_eq!(b.n_active(), 2);

        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        let (l0, l1) = (&logs[0], &logs[1]);
        assert_eq!(l0.tokens.len(), 8);
        assert_eq!(l1.tokens.len(), 2);
        assert!(
            l1.admitted_s > l0.decode_start_s,
            "r1 admitted after r0 started decoding"
        );
        assert!(
            l1.finished_s < l0.finished_s,
            "short r1 finishes while long r0 is still decoding"
        );
    }

    #[test]
    fn zero_output_request_finishes_at_admit() {
        let weights = tiny_weights();
        let mut b =
            ContinuousBatcher::new(Engine::with_slots(weights, 1), 32, Instant::now());
        let req = Request::new(7, vec![1, 2], 0);
        let log = match b.admit(req, Sampler::greedy(), 0.0, &mut NativeExec) {
            Ok(Admitted::Finished(log)) => log,
            other => panic!("expected immediate finish, got {other:?}"),
        };
        assert_eq!(log.id, 7);
        assert!(log.tokens.is_empty());
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.capacity(), 1, "slot released");
        assert_eq!(b.committed_pages(), 0, "commitment released at finish");
    }

    #[test]
    fn admission_defers_when_page_budget_committed() {
        let weights = tiny_weights();
        // 2 slots over a pool of 4 pages × 4 tokens = 16 cached tokens.
        let engine = Engine::with_paged_slots(weights, 2, 4, Some(4));
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        let mut exec = NativeExec;
        // Worst case: 5 prompt + 8 − 1 = 12 tokens → 3 pages.
        let r0 = Request::new(0, vec![1, 2, 3, 4, 5], 8);
        assert!(matches!(
            b.admit(r0, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        assert_eq!(b.committed_pages(), 3);
        // A second identical request needs 3 more pages; 3 + 3 > 4, so it
        // defers even though a session slot is free.
        assert!(b.capacity() > 0, "slot-count alone would admit");
        let r1 = Request::new(1, vec![5, 4, 3, 2, 1], 8);
        let deferred = match b.admit(r1, Sampler::greedy(), 0.0, &mut exec) {
            Ok(Admitted::Deferred(req)) => req,
            other => panic!("expected deferral, got {other:?}"),
        };
        assert_eq!(deferred.id, 1);
        assert_eq!(b.n_active(), 1, "deferred request took nothing");
        // Draining r0 releases its commitment and r1 fits.
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 1);
        assert_eq!(b.committed_pages(), 0);
        assert!(matches!(
            b.admit(deferred, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        b.drain(&mut exec);
        assert_eq!(b.engine().free_pages(), 4, "no page leaked across churn");
    }

    #[test]
    fn oversized_request_rejected_with_typed_error() {
        let weights = tiny_weights();
        // Pool of 4 pages × 4 tokens = 16 cached tokens.
        let engine = Engine::with_paged_slots(weights, 2, 4, Some(4));
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        // Worst case 10 + 20 − 1 = 29 tokens → 8 pages > 4-page pool.
        let req = Request::new(9, vec![1; 10], 20);
        let err = b.admit(req, Sampler::greedy(), 0.0, &mut NativeExec).unwrap_err();
        match err {
            AdmitError::TooLarge { id, need_tokens, need_pages, pool_pages, .. } => {
                assert_eq!(id, 9);
                assert_eq!(need_tokens, 29);
                assert_eq!(need_pages, 8);
                assert_eq!(pool_pages, 4);
            }
            other => panic!("unexpected error {other:?}"),
        }
        // The rejection wedged nothing: a small request still admits.
        let small = Request::new(10, vec![1, 2], 2);
        assert!(matches!(
            b.admit(small, Sampler::greedy(), 0.0, &mut NativeExec),
            Ok(Admitted::Active)
        ));
        let logs = b.drain(&mut NativeExec);
        assert_eq!(logs.len(), 1);
    }

    #[test]
    fn prefix_sharing_discounts_admission_budget() {
        let weights = tiny_weights();
        // 3 slots over 6 pages × 4 tokens = 24 cached tokens.
        let mut engine = Engine::with_paged_slots(weights, 3, 4, Some(6));
        engine.enable_prefix_cache();
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        let mut exec = NativeExec;
        // 9-token prompt: two full pages to share. Worst case per
        // request: 9 + 4 − 1 = 12 tokens → 3 pages, so *without* sharing
        // three of these (9 pages) could never be live together.
        let prompt: Vec<u32> = (1..=9).collect();
        let r0 = Request::new(0, prompt.clone(), 4);
        assert!(matches!(
            b.admit(r0, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        assert_eq!(b.committed_pages(), 3);
        // Same prompt again: both full prompt pages alias r0's live
        // pages, so the commitment grows only by the fresh worst case —
        // shared pages are never double-counted against their allocator.
        let r1 = Request::new(1, prompt.clone(), 4);
        assert!(matches!(
            b.admit(r1, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        assert_eq!(b.committed_pages(), 4, "aliased pages not double-counted");
        let s = b.reuse_stats();
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.prefix_hit_tokens, 8, "two full pages skipped");
        let r2 = Request::new(2, prompt.clone(), 4);
        assert!(matches!(
            b.admit(r2, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        assert_eq!(b.committed_pages(), 5, "three live in a 6-page pool");
        let mut logs = b.drain(&mut exec);
        logs.sort_by_key(|l| l.id);
        assert_eq!(logs.len(), 3);
        // Shared-prefix decode is bit-identical across the three.
        assert_eq!(logs[0].tokens, logs[1].tokens);
        assert_eq!(logs[1].tokens, logs[2].tokens);
        assert_eq!(b.committed_pages(), 0, "drain releases the whole budget");
    }

    #[test]
    fn finished_prefix_reuse_commits_shared_pages_once() {
        let weights = tiny_weights();
        let mut engine = Engine::with_paged_slots(weights, 2, 4, Some(6));
        engine.enable_prefix_cache();
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        let mut exec = NativeExec;
        let prompt: Vec<u32> = (10..19).collect();
        let r0 = Request::new(0, prompt.clone(), 4);
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec).unwrap();
        b.drain(&mut exec);
        assert_eq!(b.committed_pages(), 0);
        // r0 finished but its two full prompt pages stay cached.
        assert_eq!(b.engine().cache.cached_resident_pages(), 2);
        // A warm hit with no live allocator: the shared pages are pinned
        // into the commitment exactly once, next to the fresh page.
        let r1 = Request::new(1, prompt.clone(), 4);
        assert!(matches!(
            b.admit(r1, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        assert_eq!(b.committed_pages(), 3, "1 fresh + 2 pinned shared");
        assert_eq!(b.reuse_stats().prefix_hits, 1);
        b.drain(&mut exec);
        assert_eq!(b.committed_pages(), 0);
    }

    #[test]
    fn token_budget_schedule_is_bit_identical_to_segregated() {
        // The same request mix through the phase-segregated and the
        // token-budget schedulers: identical tokens (chunk boundaries
        // are an execution schedule, not a numerics change), with the
        // budgeted run actually mixing prefill chunks into decode
        // rounds under the chunk bound.
        let mk_reqs = || {
            vec![
                Request::new(0, vec![1, 2, 3], 6),
                Request::new(1, (1..=17).collect(), 4),
                Request::new(2, vec![9, 8], 5),
            ]
        };
        let run = |budget: Option<usize>| {
            let mut b = ContinuousBatcher::new(
                Engine::with_slots(tiny_weights(), 3),
                32,
                Instant::now(),
            );
            if let Some(n) = budget {
                b = b.with_token_budget(n).with_prefill_chunk(4);
            }
            let mut exec = NativeExec;
            for req in mk_reqs() {
                assert!(matches!(
                    b.admit(req, Sampler::greedy(), 0.0, &mut exec),
                    Ok(Admitted::Active)
                ));
            }
            let mut logs = b.drain(&mut exec);
            logs.sort_by_key(|l| l.id);
            (logs, b.round_stats())
        };
        let (seg, seg_stats) = run(None);
        let (bud, bud_stats) = run(Some(6));
        for (a, b) in seg.iter().zip(&bud) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "token budget must not change tokens");
        }
        assert_eq!(
            seg_stats.chunked_prefill_tokens, 0,
            "segregated path prefills at admission"
        );
        assert_eq!(
            bud_stats.chunked_prefill_tokens,
            3 + 17 + 2,
            "every prompt token streamed in as an in-round chunk"
        );
        assert!(
            bud_stats.max_prefill_tokens_round <= 6,
            "rounds respect the token budget: {bud_stats:?}"
        );
        assert!(bud_stats.mixed_rounds > 0, "prefill chunks rode along live decodes");
        // Per-token emission marks are complete and monotone.
        for log in &bud {
            assert_eq!(log.token_marks_s.len(), log.tokens.len());
            assert!(log.token_marks_s.windows(2).all(|w| w[1] >= w[0]));
            if !log.tokens.is_empty() {
                assert!(log.ttft_s().unwrap() >= 0.0);
                assert_eq!(log.tbt_gaps_s().len(), log.tokens.len() - 1);
            }
        }
    }

    #[test]
    fn token_budget_decode_pass_never_starves() {
        // Two live decodes alone fill a 2-token budget, yet every round
        // still carries both (the decode-starvation guarantee); the
        // prefill pass only ever spends what the decodes left.
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(tiny_weights(), 3),
            32,
            Instant::now(),
        )
        .with_token_budget(2)
        .with_prefill_chunk(2);
        let mut exec = NativeExec;
        let r0 = Request::new(0, vec![1], 4);
        let r1 = Request::new(1, vec![2], 4);
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec).unwrap();
        b.admit(r1, Sampler::greedy(), 0.0, &mut exec).unwrap();
        // Round 1 prefills both one-token prompts.
        assert!(b.decode_round(&mut exec).is_empty());
        let long = Request::new(2, (1..=9).collect(), 1);
        b.admit(long, Sampler::greedy(), 0.0, &mut exec).unwrap();
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 3, "the long prompt completes despite decode priority");
        for r in b.rounds() {
            assert!(
                r.prefill_tokens <= 2usize.saturating_sub(r.decode_tokens),
                "prefill may only spend what decodes left of the budget: {r:?}"
            );
        }
        let both_live: Vec<_> =
            b.rounds().iter().filter(|r| r.decode_tokens == 2).collect();
        assert!(!both_live.is_empty(), "rounds carried both live decodes");
    }

    #[test]
    fn zero_output_request_retires_from_prefill_round_under_budget() {
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(tiny_weights(), 1),
            32,
            Instant::now(),
        )
        .with_token_budget(8);
        let mut exec = NativeExec;
        let req = Request::new(7, vec![1, 2], 0);
        assert!(matches!(
            b.admit(req, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        assert_eq!(b.n_active(), 1, "admission no longer prefills inline");
        let logs = b.decode_round(&mut exec);
        assert_eq!(logs.len(), 1, "retired by the round that finished its prefill");
        assert!(logs[0].tokens.is_empty());
        assert_eq!(logs[0].decode_start_s, logs[0].finished_s);
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.capacity(), 1, "slot released");
        assert_eq!(b.committed_pages(), 0, "commitment released at finish");
    }

    /// Tiny config with a 16-token vocabulary: a prompt covering the
    /// whole vocab guarantees every sampled token has a 1-gram match, so
    /// the drafter always proposes something and the speculative path is
    /// exercised deterministically.
    fn spec_cfg() -> ModelConfig {
        ModelConfig {
            name: "spec-test",
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            d_ffn: 128,
            vocab_size: 16,
            qk_norm: true,
            rope_theta: 1e4,
            rms_eps: 1e-6,
            max_seq_len: 128,
        }
    }

    #[test]
    fn speculative_decode_is_bit_identical_to_vanilla() {
        let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 5);
        let prompt: Vec<u32> = (0..16).collect();
        let run = |k: usize| {
            let engine = Engine::with_paged_slots(weights.clone(), 2, 4, Some(24));
            let mut b = ContinuousBatcher::new(engine, 8, Instant::now());
            if k > 0 {
                b = b.with_speculation(k, DrafterSpec::default());
            }
            let mut exec = NativeExec;
            let req = Request::new(0, prompt.clone(), 12);
            assert!(matches!(
                b.admit(req, Sampler::greedy(), 0.0, &mut exec),
                Ok(Admitted::Active)
            ));
            let logs = b.drain(&mut exec);
            assert_eq!(b.engine().free_pages(), 24, "no page leaked (k={k})");
            assert_eq!(b.committed_pages(), 0);
            logs.into_iter().next().unwrap()
        };
        let vanilla = run(0);
        assert_eq!(vanilla.verify_calls, 0, "speculation off runs no verifies");
        assert_eq!(vanilla.tokens.len(), 12);
        for k in [1usize, 2, 4, 8] {
            let spec = run(k);
            assert_eq!(spec.tokens, vanilla.tokens, "k={k} must not change output");
            assert!(spec.verify_calls > 0, "full-vocab prompt always drafts (k={k})");
            assert!(spec.draft_accepted <= spec.draft_tokens);
            assert_eq!(spec.tokens.len(), spec.token_marks_s.len());
            assert!(spec.token_marks_s.windows(2).all(|w| w[1] >= w[0]));
            assert!(spec.accepted_tokens_per_verify().unwrap() >= 1.0);
        }
    }

    #[test]
    fn speculative_decode_preserves_stateful_sampler_stream() {
        // The hardest invariant: a seeded top-k sampler advances its RNG
        // once per sampled token. The verifier replays the sampler over
        // per-position logits in vanilla order, and the pending-token
        // handoff means the bonus token is never re-sampled — so even a
        // stateful stream cannot diverge.
        let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 7);
        let prompt: Vec<u32> = (0..16).collect();
        let run = |k: usize| {
            let mut b = ContinuousBatcher::new(
                Engine::with_slots(weights.clone(), 1),
                8,
                Instant::now(),
            );
            if k > 0 {
                b = b.with_speculation(k, DrafterSpec::parse("ngram:2").unwrap());
            }
            let mut exec = NativeExec;
            let req = Request::new(0, prompt.clone(), 10);
            assert!(matches!(
                b.admit(req, Sampler::top_k(0.8, 4, 42), 0.0, &mut exec),
                Ok(Admitted::Active)
            ));
            b.drain(&mut exec).remove(0)
        };
        let vanilla = run(0);
        let spec = run(4);
        assert_eq!(spec.tokens, vanilla.tokens, "stateful sampler stream preserved");
        assert!(spec.verify_calls > 0);
    }

    #[test]
    fn speculation_spends_only_leftover_budget() {
        let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 9);
        let prompt: Vec<u32> = (0..16).collect();
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(weights.clone(), 2),
            8,
            Instant::now(),
        )
        .with_token_budget(3)
        .with_prefill_chunk(2)
        .with_speculation(8, DrafterSpec::default());
        assert_eq!(b.speculate(), 8);
        let mut exec = NativeExec;
        let req = Request::new(0, prompt.clone(), 12);
        b.admit(req, Sampler::greedy(), 0.0, &mut exec).unwrap();
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 1);
        assert!(logs[0].verify_calls > 0);
        for r in b.rounds() {
            // One live decode: its mandatory token plus a draft capped
            // by the leftover budget — never more than the budget.
            assert!(r.decode_tokens <= 3, "draft extension respects the budget: {r:?}");
            assert!(r.prefill_tokens <= 3usize.saturating_sub(r.decode_tokens));
        }
        // Bit-identity is schedule-independent: the budgeted speculative
        // run emits what vanilla single-sequence generation emits.
        let mut reference = Engine::new(weights);
        let want = reference.generate(&prompt, 12, &mut Sampler::greedy(), &mut NativeExec);
        assert_eq!(logs[0].tokens, want.tokens);
    }

    #[test]
    fn stalled_admission_is_a_typed_error_not_a_deferral() {
        // Wedge the engine from outside the batcher: every slot taken by
        // a raw session the batcher knows nothing about. With nothing
        // active, a deferral could never resolve — admit must say so.
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(tiny_weights(), 1),
            32,
            Instant::now(),
        );
        let _held = b.engine.open_session(Sampler::greedy()).unwrap();
        assert_eq!(b.engine.free_sessions(), 0);
        assert_eq!(b.n_active(), 0);
        let req = Request::new(3, vec![1, 2], 2);
        let err = b.admit(req, Sampler::greedy(), 0.0, &mut NativeExec).unwrap_err();
        match err {
            AdmitError::Stalled { id, .. } => assert_eq!(id, 3),
            other => panic!("expected Stalled, got {other:?}"),
        }
        assert!(err.to_string().contains("nothing can progress"), "{err}");
    }

    #[test]
    fn cancel_mid_decode_keeps_delivered_tokens_and_frees_pages() {
        let engine = Engine::with_paged_slots(tiny_weights(), 2, 4, Some(8));
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        let mut exec = NativeExec;
        let handle = CancelHandle::new();
        let req = Request::new(0, vec![1, 2, 3], 16).with_cancel(handle.clone());
        b.admit(req, Sampler::greedy(), 0.0, &mut exec).unwrap();
        for _ in 0..3 {
            assert!(b.decode_round(&mut exec).is_empty());
        }
        handle.cancel();
        let logs = b.decode_round(&mut exec);
        assert_eq!(logs.len(), 1, "the round-opening sweep reaps it");
        let log = &logs[0];
        assert_eq!(log.reason, FinishReason::Cancelled);
        assert_eq!(log.tokens.len(), 3, "one token per completed round survives");
        assert_eq!(log.token_marks_s.len(), 3);
        assert!(log.finished_s >= log.decode_start_s);
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.capacity(), 2, "slot returned");
        assert_eq!(b.committed_pages(), 0, "budget released");
        assert_eq!(b.engine().free_pages(), 8, "every page back in the pool");
    }

    #[test]
    fn cancel_mid_prefill_cursor_releases_partial_pages() {
        // Token-budget path: the prompt streams in as chunks, so the
        // cancel lands while a PrefillCursor holds a half-built slot.
        let engine = Engine::with_paged_slots(tiny_weights(), 2, 4, Some(8));
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now())
            .with_token_budget(4)
            .with_prefill_chunk(4);
        let mut exec = NativeExec;
        let handle = CancelHandle::new();
        let prompt: Vec<u32> = (1..=17).collect();
        let req = Request::new(0, prompt, 4).with_cancel(handle.clone());
        b.admit(req, Sampler::greedy(), 0.0, &mut exec).unwrap();
        // One round advances the cursor by one 4-token chunk of 17.
        assert!(b.decode_round(&mut exec).is_empty());
        assert!(b.engine().free_pages() < 8, "partial prefill holds pages");
        handle.cancel();
        let logs = b.reap();
        assert_eq!(logs.len(), 1);
        assert_eq!(logs[0].reason, FinishReason::Cancelled);
        assert!(logs[0].tokens.is_empty(), "never reached decode");
        assert_eq!(b.engine().free_pages(), 8, "mid-cursor pages all released");
        assert_eq!(b.committed_pages(), 0);
        assert_eq!(b.capacity(), 2);
        // The freed slot and pages admit new work immediately.
        let next = Request::new(1, vec![7, 8, 9], 2);
        assert!(matches!(
            b.admit(next, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 1);
        assert_eq!(logs[0].reason, FinishReason::Completed);
    }

    #[test]
    fn cancel_with_pending_verify_frees_pages() {
        // Speculation leaves `pending_forward` flights between rounds —
        // the teardown path must release their pages like any other.
        let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 5);
        let engine = Engine::with_paged_slots(weights, 2, 4, Some(24));
        let mut b = ContinuousBatcher::new(engine, 8, Instant::now())
            .with_speculation(4, DrafterSpec::default());
        let mut exec = NativeExec;
        let handle = CancelHandle::new();
        let prompt: Vec<u32> = (0..16).collect();
        let req = Request::new(0, prompt, 12).with_cancel(handle.clone());
        b.admit(req, Sampler::greedy(), 0.0, &mut exec).unwrap();
        // Full-vocab prompt: the first decode round always drafts, so a
        // verify pass runs and leaves its last token pending.
        let logs = b.decode_round(&mut exec);
        assert!(logs.is_empty(), "12 tokens don't finish in one round");
        handle.cancel();
        let logs = b.reap();
        assert_eq!(logs.len(), 1);
        let log = &logs[0];
        assert_eq!(log.reason, FinishReason::Cancelled);
        assert!(log.verify_calls > 0, "cancel landed on a speculative flight");
        assert!(!log.tokens.is_empty());
        assert_eq!(b.engine().free_pages(), 24, "verify KV rolled back with the slot");
        assert_eq!(b.committed_pages(), 0);
    }

    #[test]
    fn cancelled_request_leaves_prefix_pages_adoptable() {
        let mut engine = Engine::with_paged_slots(tiny_weights(), 2, 4, Some(8));
        engine.enable_prefix_cache();
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        let mut exec = NativeExec;
        let prompt: Vec<u32> = (1..=9).collect();
        // r0 completes and registers the prompt's two full pages.
        let r0 = Request::new(0, prompt.clone(), 2);
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec).unwrap();
        b.drain(&mut exec);
        let cached = b.engine().cache.cached_resident_pages();
        assert_eq!(cached, 2, "prompt pages indexed for sharing");
        // r1 adopts them, decodes once, then is cancelled mid-decode.
        let handle = CancelHandle::new();
        let r1 = Request::new(1, prompt.clone(), 8).with_cancel(handle.clone());
        b.admit(r1, Sampler::greedy(), 0.0, &mut exec).unwrap();
        assert_eq!(b.reuse_stats().prefix_hits, 1);
        assert!(b.decode_round(&mut exec).is_empty());
        handle.cancel();
        let logs = b.reap();
        assert_eq!(logs[0].reason, FinishReason::Cancelled);
        // Teardown dropped only r1's references: the index still holds
        // the shared pages, nothing leaked.
        assert_eq!(b.engine().cache.cached_resident_pages(), 2, "still adoptable");
        assert_eq!(
            b.engine().free_pages() + b.engine().cache.cached_resident_pages(),
            8,
            "free + cached account for the whole pool"
        );
        // And a third request actually adopts them again.
        let r2 = Request::new(2, prompt, 2);
        b.admit(r2, Sampler::greedy(), 0.0, &mut exec).unwrap();
        assert_eq!(b.reuse_stats().prefix_hits, 2, "cancelled flight kept the cache warm");
        let logs = b.drain(&mut exec);
        assert_eq!(logs[0].reason, FinishReason::Completed);
    }

    #[test]
    fn reap_frees_budget_a_deferred_request_spends_immediately() {
        // Same-round reflow at the batcher level: a deferred request
        // admits the moment the cancelled one is reaped, with no decode
        // round in between.
        let engine = Engine::with_paged_slots(tiny_weights(), 2, 4, Some(4));
        let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
        let mut exec = NativeExec;
        let handle = CancelHandle::new();
        // 5 + 8 − 1 = 12 tokens → 3 of 4 pages.
        let r0 = Request::new(0, vec![1, 2, 3, 4, 5], 8).with_cancel(handle.clone());
        b.admit(r0, Sampler::greedy(), 0.0, &mut exec).unwrap();
        assert_eq!(b.committed_pages(), 3);
        let r1 = Request::new(1, vec![5, 4, 3, 2, 1], 8);
        let r1 = match b.admit(r1, Sampler::greedy(), 0.0, &mut exec) {
            Ok(Admitted::Deferred(req)) => req,
            other => panic!("expected deferral, got {other:?}"),
        };
        handle.cancel();
        let logs = b.reap();
        assert_eq!(logs.len(), 1);
        assert_eq!(logs[0].reason, FinishReason::Cancelled);
        assert_eq!(b.committed_pages(), 0, "reap returned the budget");
        assert!(matches!(
            b.admit(r1, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
        let logs = b.drain(&mut exec);
        assert_eq!(logs.len(), 1);
        assert_eq!(b.engine().free_pages(), 4, "no page leaked across the churn");
    }

    #[test]
    fn expired_deadline_reaps_before_decoding() {
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(tiny_weights(), 1),
            32,
            Instant::now(),
        );
        let mut exec = NativeExec;
        // deadline_s = 0 relative to enqueue: expired the moment the
        // round-opening sweep looks at it.
        let req = Request::new(4, vec![1, 2, 3], 8).with_deadline_s(0.0);
        b.admit(req, Sampler::greedy(), 0.0, &mut exec).unwrap();
        let logs = b.decode_round(&mut exec);
        assert_eq!(logs.len(), 1);
        assert_eq!(logs[0].reason, FinishReason::DeadlineExpired);
        assert!(logs[0].tokens.is_empty(), "reaped before sampling anything");
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.capacity(), 1, "slot back for live work");
    }

    #[test]
    fn delivery_sink_sees_every_token_and_verify_bursts_as_one_event() {
        use std::sync::{Arc, Mutex};
        let weights = ModelWeights::random(&spec_cfg(), QuantScheme::Q8_0, 5);
        let events: Arc<Mutex<Vec<TokenEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let sink_events = Arc::clone(&events);
        let mut b = ContinuousBatcher::new(Engine::with_slots(weights, 1), 8, Instant::now())
            .with_speculation(4, DrafterSpec::default())
            .with_delivery(Box::new(move |ev| {
                sink_events.lock().unwrap().push(ev);
                true
            }));
        let mut exec = NativeExec;
        let prompt: Vec<u32> = (0..16).collect();
        b.admit(Request::new(0, prompt, 12), Sampler::greedy(), 0.0, &mut exec).unwrap();
        let logs = b.drain(&mut exec);
        assert!(!b.delivery_closed());
        let log = &logs[0];
        assert_eq!(log.reason, FinishReason::Completed);
        let events = events.lock().unwrap();
        // Every token reached the sink, in order, with delivery marks.
        assert_eq!(
            events.iter().map(|e| e.token).collect::<Vec<u32>>(),
            log.tokens
        );
        assert_eq!(
            events.iter().map(|e| e.mark_s).collect::<Vec<f64>>(),
            log.token_marks_s
        );
        assert!(events.last().unwrap().done);
        assert!(events.iter().rev().skip(1).all(|e| !e.done));
        // One *event* per sink burst: a verify's accepted run shares a
        // single delivery instant, and the TBT gaps are measured over
        // events — the deflation fix (the per-accept regression test
        // lives in tests/speculative_decode.rs on a known-accepting
        // workload).
        assert_eq!(log.token_marks_s.len(), log.tokens.len());
        assert!(log.delivery_marks_s.len() <= log.tokens.len());
        assert_eq!(log.tbt_gaps_s().len(), log.delivery_marks_s.len() - 1);
        let distinct_marks = {
            let mut m = log.token_marks_s.clone();
            m.dedup();
            m.len()
        };
        assert_eq!(
            distinct_marks,
            log.delivery_marks_s.len(),
            "tokens of one event share one delivery instant"
        );
        assert_eq!(
            log.tokens.len() - log.delivery_marks_s.len(),
            log.tokens.len() - distinct_marks,
            "events and bursts agree"
        );
    }

    #[test]
    fn closed_sink_cancels_every_flight() {
        let mut b = ContinuousBatcher::new(
            Engine::with_slots(tiny_weights(), 2),
            32,
            Instant::now(),
        )
        .with_delivery(Box::new(|_| false));
        let mut exec = NativeExec;
        b.admit(Request::new(0, vec![1, 2], 8), Sampler::greedy(), 0.0, &mut exec)
            .unwrap();
        b.admit(Request::new(1, vec![3, 4], 8), Sampler::greedy(), 0.0, &mut exec)
            .unwrap();
        // The first round's deliveries latch delivery-closed; the next
        // sweep cancels everything still live.
        let first = b.decode_round(&mut exec);
        assert!(first.is_empty());
        assert!(b.delivery_closed());
        let logs = b.reap();
        assert_eq!(logs.len(), 2);
        assert!(logs.iter().all(|l| l.reason == FinishReason::Cancelled));
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.capacity(), 2);
    }

    #[test]
    fn performance_saturates_beyond_two_lanes() {
        // Paper Fig 16: 1 → 2 lanes improves; ≥4 lanes degrades on the
        // dual-core host.
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[1, 2, 4, 8],
            TransferMode::Coalesced,
        );
        assert!(pts[1].e2e_s < pts[0].e2e_s, "2 lanes beat 1");
        assert!(pts[2].e2e_s > pts[1].e2e_s, "4 lanes degrade vs 2");
        assert!(pts[3].e2e_s > pts[2].e2e_s, "8 lanes degrade further");
        assert_eq!(best_lanes(&pts), 2, "paper's chosen configuration");
    }

    #[test]
    fn exec_time_monotonically_decreases_with_lanes() {
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[1, 2, 4, 8],
            TransferMode::Coalesced,
        );
        for w in pts.windows(2) {
            assert!(
                w[1].exec_s < w[0].exec_s,
                "EXEC itself scales: {} vs {}",
                w[1].exec_s,
                w[0].exec_s
            );
        }
    }

    #[test]
    fn host_time_grows_beyond_host_cores() {
        let pts = lane_sweep(
            &workload(),
            &ImaxDevice::fpga(2),
            &[2, 8],
            TransferMode::Coalesced,
        );
        assert!(pts[1].host_s > pts[0].host_s);
    }
}
