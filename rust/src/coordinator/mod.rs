//! The hybrid host/IMAX execution coordinator — the paper's system
//! contribution (§III.A): task partitioning between the Arm host and the
//! CGLA, offload policy, lane scheduling, and the serving loop.
//!
//! * [`offload`] — the LMM-fit + energy-benefit offload decision and the
//!   Table 2 offload-ratio accounting.
//! * [`hybrid`] — the paper-scale workload simulator (prefill as one
//!   batched ubatch, decode per token) producing Fig 11/15 numbers.
//! * [`phases`] — instrumentation wrapper tying the *functional* tiny-
//!   model engine to the same cost model (ubatch-aware: batched prefill
//!   amortizes weight LOAD and configuration).
//! * [`scheduler`] — the continuous-batching session scheduler behind
//!   `serve`, plus the Fig 16 lane-scalability sweep with the host
//!   bottleneck model.
//! * [`serve`] — continuous-batching request serving over std threads
//!   and the [`crate::runtime::backend::BackendRegistry`] (the
//!   examples/serve_e2e.rs driver).

#![warn(missing_docs)]

pub mod hybrid;
pub mod offload;
pub mod phases;
pub mod scheduler;
pub mod serve;

pub use hybrid::{simulate, Workload, WorkloadRun};
pub use offload::{OffloadPolicy, OffloadStats};
pub use phases::{InstrumentedExec, RoundCost};
pub use scheduler::{
    AdaptiveBudget, AdmitError, Admitted, CancelHandle, ContinuousBatcher, DeliverySink,
    FinishReason, Request, RoundStats, RoundTokens, SchedPolicy, SessionLog, TenantFairness,
    TokenEvent,
};
pub use serve::{
    serve, serve_streaming, serve_trace, serve_trace_streaming, serve_with, Completion,
    ServeError, ServeOptions, ServeReport, StreamingServe, TenantReport, ADMIT_SCAN_WINDOW,
};
