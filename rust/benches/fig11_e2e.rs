//! Bench: regenerate paper Fig 11 — E2E latency for all 54 workloads on
//! all five platforms — and time the full-grid evaluation.
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("fig11 — E2E latency grid");
    // Time a single-workload evaluation (the harness unit of work).
    let w = imax_llm::harness::workloads::grid()[0].clone();
    set.bench("eval_workload(0.6B Q8_0 [8:1])", || exp::eval_workload(&w));
    set.report();

    // Produce the figure itself.
    let grid = exp::eval_grid();
    exp::fig11(&grid).print();
    println!("(series written to reports/fig11_latency.csv)");
}
