//! Bench: chunked-prefill fairness — what the token-budget iteration
//! scheduler buys when a long prompt arrives mid-serve.
//!
//! Three short requests are decoding when a 96-token prompt is admitted.
//! Phase-segregated, its whole prefill runs at admission and every live
//! decode stalls behind it; token-budgeted (budget 8, chunk 4), it
//! streams in as bounded chunks riding along the decode rounds. Both
//! schedules are served through a [`ContinuousBatcher`] under the
//! instrumented IMAX cost model and compared on:
//!
//! * decode time-between-tokens p99/max over the short requests (wall
//!   clock, the tail-latency metric serving stacks are judged on),
//! * the worst modeled gap between decode rounds and the modeled bytes
//!   streamed host→LMM — the paper's transfer-bottleneck quantities,
//!   per round via [`InstrumentedExec::rounds`],
//! * prefill tokens per round (the fairness bound itself).
//!
//! With `BENCH_JSON=path` a machine-readable summary is written for the
//! CI `bench-smoke` job (`scripts/check_bench_regression.py` gates the
//! deterministic counters against `BENCH_baseline.json`).

use std::time::Instant;

use imax_llm::coordinator::{
    Admitted, ContinuousBatcher, InstrumentedExec, OffloadPolicy, Request, RoundStats,
    SessionLog,
};
use imax_llm::imax::{ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::engine::NativeExec;
use imax_llm::model::{Engine, ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::util::bench::JsonMetrics;
use imax_llm::util::report::Table;
use imax_llm::util::stats::percentile;

const LONG_PROMPT: usize = 96;
const TOKEN_BUDGET: usize = 8;
const PREFILL_CHUNK: usize = 4;
const N_SHORT: usize = 3;
const SHORT_N_OUT: usize = 16;

fn weights() -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 23)
}

struct RunStats {
    tokens: Vec<Vec<u32>>,
    /// TBT gaps of the short requests (wall seconds).
    short_gaps_s: Vec<f64>,
    /// Worst modeled seconds between consecutive decode-round
    /// completions (admission prefill lands in the following gap).
    worst_modeled_gap_s: f64,
    /// Modeled operand bytes streamed host→LMM over the whole run.
    streamed_bytes: u64,
    /// Largest modeled byte volume any one round streamed (0 when the
    /// scheduler never marked a round, i.e. nothing was budgeted).
    max_round_streamed_bytes: u64,
    rounds: RoundStats,
}

/// One settled round plus a modeled-timeline mark: the gap between
/// consecutive marks is the modeled time a live decode waited for its
/// next token (admission-time prefill lands in the following gap).
fn settle_round(
    b: &mut ContinuousBatcher,
    exec: &mut InstrumentedExec<NativeExec>,
    logs: &mut Vec<SessionLog>,
    worst_gap: &mut f64,
    modeled_mark: &mut f64,
) {
    logs.extend(b.decode_round(exec));
    let cum = exec.modeled.total().total();
    *worst_gap = (*worst_gap).max(cum - *modeled_mark);
    *modeled_mark = cum;
}

fn run(budgeted: bool) -> RunStats {
    let mut exec = InstrumentedExec::new(
        NativeExec,
        ImaxDevice::fpga(2),
        OffloadPolicy::new(LmmConfig::new(64)),
        TransferMode::Coalesced,
    );
    let mut b = ContinuousBatcher::new(Engine::with_slots(weights(), 4), 32, Instant::now());
    if budgeted {
        b = b.with_token_budget(TOKEN_BUDGET).with_prefill_chunk(PREFILL_CHUNK);
    }
    let mut modeled_mark = 0.0f64;
    let mut worst_gap = 0.0f64;
    let mut logs = Vec::new();
    for id in 0..N_SHORT {
        let req = Request::new(id, vec![1 + id as u32, 2, 3, 4], SHORT_N_OUT);
        assert!(matches!(
            b.admit(req, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
    }
    for _ in 0..3 {
        settle_round(&mut b, &mut exec, &mut logs, &mut worst_gap, &mut modeled_mark);
    }
    let long_prompt: Vec<u32> = (0..LONG_PROMPT).map(|i| 1 + (i % 100) as u32).collect();
    let long = Request::new(N_SHORT, long_prompt, 2);
    assert!(matches!(
        b.admit(long, Sampler::greedy(), 0.0, &mut exec),
        Ok(Admitted::Active)
    ));
    while b.n_active() > 0 {
        settle_round(&mut b, &mut exec, &mut logs, &mut worst_gap, &mut modeled_mark);
    }
    logs.sort_by_key(|l| l.id);
    RunStats {
        tokens: logs.iter().map(|l| l.tokens.clone()).collect(),
        short_gaps_s: logs
            .iter()
            .filter(|l| l.id < N_SHORT)
            .flat_map(|l| l.tbt_gaps_s())
            .collect(),
        worst_modeled_gap_s: worst_gap,
        streamed_bytes: exec.streamed_bytes,
        max_round_streamed_bytes: exec
            .rounds
            .iter()
            .map(|r| r.streamed_bytes)
            .max()
            .unwrap_or(0),
        rounds: b.round_stats(),
    }
}

fn main() {
    let seg = run(false);
    let bud = run(true);
    assert_eq!(seg.tokens, bud.tokens, "scheduling must not change tokens");
    assert!(
        bud.rounds.max_prefill_tokens_decode_round <= PREFILL_CHUNK,
        "fairness bound violated: {:?}",
        bud.rounds
    );

    let p99 = |xs: &[f64]| percentile(xs, 99.0);
    let max = |xs: &[f64]| xs.iter().cloned().fold(0.0f64, f64::max);
    let mut t = Table::new(
        "chunked-prefill fairness: long prompt arriving over live decodes \
         (modeled imax:fpga2)",
        &["metric", "segregated", "token-budget"],
    );
    t.row(vec![
        "decode TBT p99, shorts (wall s)".to_string(),
        format!("{:.6}", p99(&seg.short_gaps_s)),
        format!("{:.6}", p99(&bud.short_gaps_s)),
    ]);
    t.row(vec![
        "decode TBT max, shorts (wall s)".to_string(),
        format!("{:.6}", max(&seg.short_gaps_s)),
        format!("{:.6}", max(&bud.short_gaps_s)),
    ]);
    t.row(vec![
        "worst modeled gap between decode rounds (s)".to_string(),
        format!("{:.6}", seg.worst_modeled_gap_s),
        format!("{:.6}", bud.worst_modeled_gap_s),
    ]);
    t.row(vec![
        "modeled bytes streamed host->LMM".to_string(),
        seg.streamed_bytes.to_string(),
        bud.streamed_bytes.to_string(),
    ]);
    t.row(vec![
        "max bytes streamed in one round".to_string(),
        "-".to_string(),
        bud.max_round_streamed_bytes.to_string(),
    ]);
    t.row(vec![
        "chunked prefill tokens (per round / max)".to_string(),
        "0 (prefill at admission)".to_string(),
        format!(
            "{} ({:.1} per prefill round, max {})",
            bud.rounds.chunked_prefill_tokens,
            bud.rounds.prefill_tokens_per_round(),
            bud.rounds.max_prefill_tokens_round
        ),
    ]);
    t.print();

    let mut json = JsonMetrics::new("fairness");
    json.push("tbt_p99_wall_s_segregated", p99(&seg.short_gaps_s), "lower", false);
    json.push("tbt_p99_wall_s_budgeted", p99(&bud.short_gaps_s), "lower", false);
    json.push("tbt_max_wall_s_budgeted", max(&bud.short_gaps_s), "lower", false);
    json.push("worst_modeled_gap_s_segregated", seg.worst_modeled_gap_s, "lower", true);
    json.push("worst_modeled_gap_s_budgeted", bud.worst_modeled_gap_s, "lower", true);
    json.push(
        "modeled_gap_ratio_seg_over_budget",
        seg.worst_modeled_gap_s / bud.worst_modeled_gap_s,
        "higher",
        true,
    );
    json.push(
        "max_prefill_tokens_round_budgeted",
        bud.rounds.max_prefill_tokens_round as f64,
        "lower",
        true,
    );
    json.push(
        "max_prefill_tokens_decode_round_budgeted",
        bud.rounds.max_prefill_tokens_decode_round as f64,
        "lower",
        true,
    );
    json.push(
        "chunked_prefill_tokens_budgeted",
        bud.rounds.chunked_prefill_tokens as f64,
        "higher",
        true,
    );
    json.push("streamed_bytes_budgeted", bud.streamed_bytes as f64, "lower", true);
    json.push(
        "max_round_streamed_bytes_budgeted",
        bud.max_round_streamed_bytes as f64,
        "lower",
        true,
    );
    json.write_if_requested().expect("BENCH_JSON path writable");
}
