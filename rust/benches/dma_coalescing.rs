//! Bench: the §III.D DMA transfer-coalescing ablation (paper: LOAD ×1.2,
//! DRAIN ×4.8 vs the naive per-array implementation).
use imax_llm::harness::experiments as exp;
use imax_llm::imax::{dma, ImaxDevice, TransferMode};
use imax_llm::util::bench::BenchSet;

fn main() {
    // Micro: the transfer cost model itself.
    let dev = ImaxDevice::fpga(2);
    let mut set = BenchSet::new("dma — transfer cost model");
    let t = dma::Transfer {
        bytes: 256 * 1024,
        n_arrays: 4,
    };
    set.bench("load_seconds(coalesced)", || {
        dma::load_seconds(&dev, t, TransferMode::Coalesced)
    });
    set.bench("load_seconds(naive)", || {
        dma::load_seconds(&dev, t, TransferMode::Naive)
    });
    set.report();

    exp::ablate_dma().print();
}
