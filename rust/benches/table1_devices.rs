//! Bench: regenerate paper Table 1 — device specifications.
use imax_llm::harness::experiments as exp;

fn main() {
    exp::table1().print();
}
