//! Bench: regenerate paper Fig 16 — lane scalability under the dual-core
//! host bottleneck.
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("fig16 — lane scaling");
    set.bench("lane_sweep(1,2,4,8)", exp::fig16);
    set.report();
    exp::fig16().print();
    println!("(series written to reports/fig16_scaling.csv)");
}
