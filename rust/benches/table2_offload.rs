//! Bench: regenerate paper Table 2 — offload ratios per model / quant /
//! kernel format at the 64 KB LMM deployment.
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("table2 — offload ratios");
    set.bench("offload_ratios(6 model-scheme combos)", exp::table2);
    set.report();
    exp::table2().print();
    println!("(series written to reports/table2_offload.csv)");
}
