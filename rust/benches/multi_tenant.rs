//! Bench: multi-tenant serving — WFQ admission fairness, the
//! closed-loop adaptive token budget, and SLO attainment on the
//! committed scenario file.
//!
//! Three segments, all deterministic enough to gate in CI:
//!
//! 1. **WFQ fairness.** Twelve equal-cost requests queue at once: six
//!    `bulk` (ids 0–5) ahead of six `vip` (ids 6–11), weights 1 vs 4,
//!    one worker with a single slot so admissions are strictly serial.
//!    FIFO admits in arrival order (vip mean admission rank 8.5 of
//!    0–11); weighted fair queuing charges each admission at
//!    `tokens / weight`, so the vip class is pulled forward to ranks
//!    {1,2,3,4,6,7} — mean 3.83. The gate is the rank *gain* (≈ 4.67,
//!    floor 2.0), and the bench asserts token streams are identical
//!    either way: scheduling moved, outputs did not.
//!
//! 2. **Adaptive budget.** A mixed-length batch served on the modeled
//!    IMAX backend with `--adaptive-budget 4:64` (seeded low at 6):
//!    every settled round feeds its LOAD/EXEC balance back into the
//!    next round's token budget. Gates that the controller actually
//!    stepped (`adaptive_rounds`, floor 1) and asserts bit-identical
//!    tokens against a fixed-budget run.
//!
//! 3. **Scenario replay.** `examples/scenarios/mixed_tenants.scn` — the
//!    committed three-tenant bursty trace — replayed through
//!    `serve_trace` under WFQ with the scenario's own weights and SLO
//!    targets. The targets are generous on purpose: the gate is "the
//!    stack meets easy SLOs under mixed load on any machine", floor
//!    0.9 overall and 0.75 for the worst tenant, with all 48 requests
//!    served.
//!
//! The shapes are already small (tiny model, ≤ 48 requests), so
//! `IMAX_BENCH_QUICK` changes nothing. With `BENCH_JSON=path` a
//! machine-readable summary is written for the CI `bench-smoke` job
//! (`scripts/check_bench_regression.py` gates the counters against
//! `BENCH_baseline.json`).

use imax_llm::coordinator::{
    serve_trace, serve_with, AdaptiveBudget, Request, SchedPolicy, ServeOptions, ServeReport,
};
use imax_llm::harness::scenario::Scenario;
use imax_llm::harness::workloads::templated_prompt;
use imax_llm::model::{ModelConfig, ModelWeights, QuantScheme};
use imax_llm::runtime::{ExecSpec, ImaxSpec};
use imax_llm::util::bench::JsonMetrics;
use imax_llm::util::report::Table;

const N_BULK: usize = 6;
const N_VIP: usize = 6;
const FAIR_N_IN: usize = 8;
const FAIR_N_OUT: usize = 4;

fn tiny_weights() -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 31)
}

/// The twelve-request fairness workload: bulk ids 0–5 queued ahead of
/// vip ids 6–11, every request the same cost so only the scheduler can
/// tell the classes apart.
fn fairness_requests() -> Vec<Request> {
    (0..N_BULK + N_VIP)
        .map(|id| {
            let tenant = if id < N_BULK { "bulk" } else { "vip" };
            Request::new(id, templated_prompt(id, FAIR_N_IN, 64), FAIR_N_OUT)
                .with_tenant(tenant.to_string())
        })
        .collect()
}

/// Mean 0-based admission rank of the vip class: completions sorted by
/// `admitted_s` (admissions are strictly serial under a single slot).
fn vip_mean_rank(rep: &ServeReport) -> f64 {
    let mut order: Vec<(f64, usize)> =
        rep.completions.iter().map(|c| (c.admitted_s, c.id)).collect();
    order.sort_by(|a, b| a.0.total_cmp(&b.0));
    let ranks: Vec<f64> = order
        .iter()
        .enumerate()
        .filter(|(_, &(_, id))| id >= N_BULK)
        .map(|(rank, _)| rank as f64)
        .collect();
    assert_eq!(ranks.len(), N_VIP);
    ranks.iter().sum::<f64>() / ranks.len() as f64
}

/// Tokens per request id, for schedule-only invariance checks.
fn tokens_by_id(rep: &ServeReport) -> Vec<(usize, Vec<u32>)> {
    let mut v: Vec<(usize, Vec<u32>)> =
        rep.completions.iter().map(|c| (c.id, c.tokens.clone())).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

fn run_fairness(sched: SchedPolicy) -> ServeReport {
    let opts = ServeOptions {
        slots_per_worker: 1,
        admit_window: 0,
        sched,
        tenant_weights: vec![("bulk".to_string(), 1.0), ("vip".to_string(), 4.0)],
        ..ServeOptions::default()
    };
    serve_with(&tiny_weights(), fairness_requests(), 1, &opts).expect("native serve builds")
}

fn adaptive_requests() -> Vec<Request> {
    (0..6)
        .map(|id| {
            let prompt = (0..3 + 4 * id).map(|i| 1 + (i % 50) as u32).collect();
            Request::new(id, prompt, 4)
        })
        .collect()
}

fn main() {
    // ---- Segment 1: WFQ admission fairness --------------------------
    let fifo = run_fairness(SchedPolicy::Fifo);
    let wfq = run_fairness(SchedPolicy::Wfq);
    assert_eq!(
        tokens_by_id(&fifo),
        tokens_by_id(&wfq),
        "WFQ must reorder admissions, never change tokens"
    );
    let fifo_rank = vip_mean_rank(&fifo);
    let wfq_rank = vip_mean_rank(&wfq);
    let rank_gain = fifo_rank - wfq_rank;
    assert!(
        rank_gain > 0.0,
        "weight-4 vip class must be admitted earlier under WFQ: \
         fifo mean rank {fifo_rank:.2}, wfq {wfq_rank:.2}"
    );

    // ---- Segment 2: adaptive budget on the modeled backend ----------
    let adaptive_opts = ServeOptions {
        spec: ExecSpec::Imax(ImaxSpec::default()),
        token_budget: Some(6),
        adaptive_budget: Some(AdaptiveBudget::new(4, 64)),
        prefill_chunk: Some(3),
        adaptive_chunk: true,
        ..ServeOptions::default()
    };
    let adaptive =
        serve_with(&tiny_weights(), adaptive_requests(), 1, &adaptive_opts).expect("imax serve");
    let fixed_opts = ServeOptions {
        spec: ExecSpec::Imax(ImaxSpec::default()),
        token_budget: Some(6),
        prefill_chunk: Some(3),
        ..ServeOptions::default()
    };
    let fixed =
        serve_with(&tiny_weights(), adaptive_requests(), 1, &fixed_opts).expect("imax serve");
    assert_eq!(
        tokens_by_id(&adaptive),
        tokens_by_id(&fixed),
        "the budget controller must be schedule-only"
    );
    let adaptive_rounds = adaptive.rounds.adaptive_rounds;
    let (budget_lo, budget_hi) = (adaptive.rounds.budget_lo, adaptive.rounds.budget_hi);
    assert!(adaptive_rounds > 0, "modeled backend must step the controller");
    assert!(
        (4..=64).contains(&budget_lo) && (4..=64).contains(&budget_hi) && budget_lo <= budget_hi,
        "controller escaped [4, 64]: lo={budget_lo} hi={budget_hi}"
    );

    // ---- Segment 3: committed scenario replay under SLOs ------------
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios/mixed_tenants.scn");
    let text = std::fs::read_to_string(path).expect("committed scenario file");
    let sc = Scenario::parse(&text).expect("committed scenario parses");
    let arrivals = sc.arrivals();
    let trace: Vec<(Request, f64)> = arrivals
        .into_iter()
        .map(|a| {
            assert!(a.cancel.is_none(), "the CI scenario carries no cancels");
            (a.request, a.at_s)
        })
        .collect();
    let scn_opts = ServeOptions {
        sched: SchedPolicy::Wfq,
        tenant_weights: sc.tenant_weights(),
        prefix_cache: true,
        slo_ttft_s: Some(sc.slo_ttft_s),
        slo_tbt_s: Some(sc.slo_tbt_s),
        ..ServeOptions::default()
    };
    let rep = serve_trace(&tiny_weights(), trace, 2, &scn_opts).expect("scenario serve");
    let served = rep.completions.iter().filter(|c| c.error.is_none()).count();
    assert_eq!(rep.tenants.len(), sc.tenants.len(), "every tenant class reports");
    let attainment = rep.slo_attainment.expect("SLO targets were set");
    let worst_tenant = rep
        .tenants
        .iter()
        .filter_map(|t| t.slo_attainment)
        .fold(f64::INFINITY, f64::min);
    assert!(worst_tenant.is_finite(), "every tenant served something");

    // ---- Report -----------------------------------------------------
    let mut t = Table::new("multi-tenant serving", &["segment", "metric", "value"]);
    t.row(vec!["wfq".into(), "vip mean rank (fifo)".into(), format!("{fifo_rank:.2}")]);
    t.row(vec!["wfq".into(), "vip mean rank (wfq)".into(), format!("{wfq_rank:.2}")]);
    t.row(vec!["wfq".into(), "rank gain".into(), format!("{rank_gain:.2}")]);
    t.row(vec!["adaptive".into(), "controller steps".into(), format!("{adaptive_rounds}")]);
    t.row(vec!["adaptive".into(), "budget walk".into(), format!("[{budget_lo}, {budget_hi}]")]);
    t.row(vec!["scenario".into(), "served / requests".into(), format!("{served} / {}", sc.n)]);
    t.row(vec!["scenario".into(), "SLO attainment".into(), format!("{attainment:.3}")]);
    t.row(vec!["scenario".into(), "worst-tenant attainment".into(), format!("{worst_tenant:.3}")]);
    t.row(vec!["scenario".into(), "wall (s)".into(), format!("{:.3}", rep.wall_s)]);
    println!("{}", t.render());

    let mut m = JsonMetrics::new("multi_tenant");
    m.push("fairness_rank_gain", rank_gain, "higher", true);
    m.push("wfq_vip_mean_rank", wfq_rank, "lower", false);
    m.push("adaptive_rounds", adaptive_rounds as f64, "higher", true);
    m.push("adaptive_budget_span", (budget_hi - budget_lo) as f64, "higher", false);
    m.push("scenario_served", served as f64, "higher", true);
    m.push("scenario_slo_attainment", attainment, "higher", true);
    m.push("scenario_worst_tenant_slo_attainment", worst_tenant, "higher", true);
    m.push("scenario_wall_s", rep.wall_s, "lower", false);
    m.write_if_requested().expect("BENCH_JSON write");
}
