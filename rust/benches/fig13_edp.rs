//! Bench: regenerate paper Fig 13 — EDP comparison by device.
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("fig13 — EDP grid");
    let w = imax_llm::harness::workloads::find(
        "0.6b",
        imax_llm::model::QuantScheme::Q3KS,
        32,
        16,
    )
    .unwrap();
    set.bench("eval_workload(0.6B Q3_K_S [32:16])", || exp::eval_workload(&w));
    set.report();

    let grid = exp::eval_grid();
    exp::fig13(&grid).print();
    println!("(series written to reports/fig13_edp.csv)");
}
