//! Bench: regenerate paper Fig 14 — PDP vs LMM size (16..512 KB sweep).
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("fig14 — LMM sweep");
    set.bench("lmm_sweep(6 sizes x 6 workloads)", || {
        exp::fig14(&[16, 32, 64, 128, 256, 512])
    });
    set.report();
    exp::fig14(&[16, 32, 64, 128, 256, 512]).print();
    println!("(series written to reports/fig14_lmm_pdp.csv)");
}
