//! Bench: quantized KV pages — what q8_0 page encoding buys on the
//! paper's LOAD-bound decode regime.
//!
//! Decode streams the live KV window from host to the LMM every step,
//! so the cache encoding directly scales the bytes that bound decode.
//! `--kv-quant q8_0` stores pages as 34-byte q8_0 blocks instead of
//! f16 rows: 64 bytes per 32 elements become 34, a 64/34 ≈ 1.88× cut
//! in both resident footprint and per-step stream traffic. This bench
//! serves the same templated workload through a [`ContinuousBatcher`]
//! twice — once per [`KvScheme`] — over identically shaped page pools
//! and compares:
//!
//! * peak resident KV bytes (page-granular, dedup-aware; the pool
//!   allocates the same page count under either scheme, so the ratio
//!   is exactly the per-page encoding ratio),
//! * attention KV stream bytes: whole pages covering each step's
//!   context, K and V, every layer — the transfer unit the host-swap
//!   and offload paths actually move.
//!
//! Both ratios gate at > 1.7 (floor semantics in `BENCH_baseline.json`;
//! the exact value is 64/34 ≈ 1.882). The shape is already quick
//! (2-layer 16-vocab model, 4 requests), so `IMAX_BENCH_QUICK` changes
//! nothing.
//!
//! With `BENCH_JSON=path` a machine-readable summary is written for the
//! CI `bench-smoke` job (`scripts/check_bench_regression.py` gates the
//! deterministic counters against `BENCH_baseline.json`).

use std::time::Instant;

use imax_llm::coordinator::{Admitted, ContinuousBatcher, Request, SessionLog};
use imax_llm::harness::workloads::templated_prompt;
use imax_llm::model::engine::{KernelExec, MatvecExec, NativeExec};
use imax_llm::model::{
    Engine, KvScheme, MatvecOp, ModelConfig, ModelWeights, OpKind, QuantScheme, Sampler,
};
use imax_llm::tensor::{ActQuant, QTensor};
use imax_llm::util::bench::JsonMetrics;
use imax_llm::util::ceil_div;
use imax_llm::util::report::Table;

const N_REQ: usize = 4;
const PROMPT_LEN: usize = 40;
const N_OUT: usize = 24;
const PAGE_SIZE: usize = 8;
const N_SLOTS: usize = 4;

/// kv_dim = 32 (one q8_0 block per row): the smallest shape the q8_0
/// pool accepts, so the bench stays fast while exercising the exact
/// block geometry the encoding-ratio gates are about.
fn cfg() -> ModelConfig {
    ModelConfig {
        name: "kv-quant-bench",
        n_layers: 2,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        d_ffn: 128,
        vocab_size: 16,
        qk_norm: true,
        rope_theta: 1e4,
        rms_eps: 1e-6,
        max_seq_len: 128,
    }
}

fn weights() -> ModelWeights {
    ModelWeights::random(&cfg(), QuantScheme::Q8_0, 29)
}

/// Executes natively and accounts the attention KV stream at page
/// granularity: one `AttnScore` op per token per layer means one K+V
/// window transfer of `2 × pages(ctx) × page_size × row_bytes(kv_dim)`
/// bytes in the pool's encoding — the same sizing as
/// `KvCache::stream_bytes_per_layer`, observed per executed step.
struct AttnStream {
    inner: NativeExec,
    row_bytes: usize,
    n_heads: usize,
    kv_stream_bytes: u64,
}

impl AttnStream {
    fn new(scheme: KvScheme) -> AttnStream {
        AttnStream {
            inner: NativeExec,
            row_bytes: scheme.row_bytes(cfg().n_kv_heads * cfg().head_dim),
            n_heads: cfg().n_heads,
            kv_stream_bytes: 0,
        }
    }
}

impl MatvecExec for AttnStream {
    fn linear(&mut self, op: &MatvecOp, w: &QTensor, act: &ActQuant, out: &mut [f32]) {
        self.inner.linear(op, w, act, out);
    }

    fn attn(&mut self, op: &MatvecOp) {
        if matches!(op.kind, OpKind::AttnScore) {
            let ctx = op.rows / self.n_heads;
            let pages = ceil_div(ctx, PAGE_SIZE);
            self.kv_stream_bytes += (2 * pages * PAGE_SIZE * self.row_bytes) as u64;
        }
    }
}

impl KernelExec for AttnStream {}

struct RunStats {
    peak_resident_bytes: usize,
    kv_stream_bytes: u64,
    total_out_tokens: usize,
}

fn run(scheme: KvScheme) -> RunStats {
    let mut exec = AttnStream::new(scheme);
    let engine = Engine::with_paged_slots_kv(weights(), N_SLOTS, PAGE_SIZE, None, scheme);
    let mut b = ContinuousBatcher::new(engine, 32, Instant::now());
    for id in 0..N_REQ {
        let req = Request::new(id, templated_prompt(id, PROMPT_LEN, cfg().vocab_size), N_OUT);
        assert!(matches!(
            b.admit(req, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
    }
    let mut logs: Vec<SessionLog> = Vec::new();
    while b.n_active() > 0 {
        logs.extend(b.decode_round(&mut exec));
    }
    RunStats {
        peak_resident_bytes: b.engine().cache.peak_resident_bytes(),
        kv_stream_bytes: exec.kv_stream_bytes,
        total_out_tokens: logs.iter().map(|l| l.tokens.len()).sum(),
    }
}

fn main() {
    let f16 = run(KvScheme::F16);
    let q8 = run(KvScheme::Q8_0);
    assert_eq!(f16.total_out_tokens, N_REQ * N_OUT, "f16 run must drain the workload");
    assert_eq!(q8.total_out_tokens, N_REQ * N_OUT, "q8_0 run must drain the workload");

    // Same request lengths → same page allocation under either scheme,
    // so both ratios are exactly the per-row encoding ratio 64/34.
    let resident_ratio = f16.peak_resident_bytes as f64 / q8.peak_resident_bytes as f64;
    let stream_ratio = f16.kv_stream_bytes as f64 / q8.kv_stream_bytes as f64;
    let expect = 64.0 / 34.0;
    assert!(
        (resident_ratio - expect).abs() < 1e-9,
        "resident ratio {resident_ratio} must equal 64/34"
    );
    assert!(
        (stream_ratio - expect).abs() < 1e-9,
        "stream ratio {stream_ratio} must equal 64/34"
    );
    assert!(resident_ratio > 1.7, "resident gate: {resident_ratio} <= 1.7");
    assert!(stream_ratio > 1.7, "stream gate: {stream_ratio} <= 1.7");

    let mut t = Table::new(
        "quantized KV pages: f16 vs q8_0 pool encoding, same serve shape",
        &["metric", "f16", "q8_0"],
    );
    t.row(vec![
        "peak resident KV bytes".to_string(),
        f16.peak_resident_bytes.to_string(),
        q8.peak_resident_bytes.to_string(),
    ]);
    t.row(vec![
        "attention KV stream bytes".to_string(),
        f16.kv_stream_bytes.to_string(),
        q8.kv_stream_bytes.to_string(),
    ]);
    t.row(vec![
        "resident ratio f16/q8_0".to_string(),
        "-".to_string(),
        format!("{resident_ratio:.3}"),
    ]);
    t.row(vec![
        "stream ratio f16/q8_0".to_string(),
        "-".to_string(),
        format!("{stream_ratio:.3}"),
    ]);
    t.print();

    let mut json = JsonMetrics::new("kv_quant");
    json.push("peak_resident_bytes_f16", f16.peak_resident_bytes as f64, "lower", false);
    json.push("peak_resident_bytes_q8", q8.peak_resident_bytes as f64, "lower", false);
    json.push("stream_bytes_f16", f16.kv_stream_bytes as f64, "lower", false);
    json.push("stream_bytes_q8", q8.kv_stream_bytes as f64, "lower", false);
    json.push("resident_bytes_ratio_f16_over_q8", resident_ratio, "higher", true);
    json.push("stream_bytes_ratio", stream_ratio, "higher", true);
    json.write_if_requested().expect("BENCH_JSON path writable");
}
