//! Bench: the native quantized dot-product kernels (the Rust analogues of
//! paper Figs 5-9) — throughput per format, plus end-to-end tiny-model
//! decode. This is the L3 hot path the §Perf pass optimizes.
use imax_llm::model::{Engine, ModelConfig, ModelWeights, NativeExec, QuantScheme, Sampler};
use imax_llm::quant::{fp16, q3_k, q6_k, q8_0, q8_k};
use imax_llm::util::bench::{bb, BenchSet};
use imax_llm::util::f16::F16;
use imax_llm::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(9);
    let k = 4096usize;
    let mut w = vec![0.0f32; k];
    let mut a = vec![0.0f32; k];
    rng.fill_normal(&mut w, 0.5);
    rng.fill_normal(&mut a, 1.0);

    let w8 = q8_0::quantize_row(&w);
    let a8 = q8_0::quantize_row(&a);
    let w6 = q6_k::quantize_row(&w);
    let w3 = q3_k::quantize_row(&w);
    let ak = q8_k::quantize_row(&a);
    let wh: Vec<F16> = w.iter().map(|&v| F16::from_f32(v)).collect();

    let mut set = BenchSet::new("quantized vec_dot kernels (K=4096)");
    set.bench_elems("fp16_dot", k as f64, || bb(fp16::vec_dot_f16(&wh, &a)));
    set.bench_elems("q8_0_dot", k as f64, || bb(q8_0::vec_dot(&w8, &a8)));
    set.bench_elems("q6_k_dot", k as f64, || bb(q6_k::vec_dot(&w6, &ak)));
    set.bench_elems("q3_k_dot", k as f64, || bb(q3_k::vec_dot(&w3, &ak)));
    set.bench_elems("q3_k_dot_cvt53", k as f64, || {
        bb(q3_k::vec_dot_cvt53(&w3, &ak))
    });
    set.bench_elems("quantize_row_q8_0", k as f64, || bb(q8_0::quantize_row(&a)));
    set.bench_elems("quantize_row_q8_k", k as f64, || bb(q8_k::quantize_row(&a)));
    set.report();

    // End-to-end tiny-model token throughput (the functional hot path).
    let cfg = ModelConfig::tiny();
    let mut set2 = BenchSet::new("tiny-model end-to-end");
    for scheme in [QuantScheme::F16, QuantScheme::Q8_0, QuantScheme::Q3KS] {
        let mut engine = Engine::new(ModelWeights::random(&cfg, scheme, 3));
        set2.bench(&format!("decode_token({})", scheme.name()), || {
            if engine.cache.len() > 200 {
                engine.reset();
            }
            let phase = if engine.cache.is_empty() {
                imax_llm::model::Phase::Prefill
            } else {
                imax_llm::model::Phase::Decode
            };
            engine.forward(7, phase, true, &mut NativeExec)
        });
    }
    // Full request.
    let mut engine = Engine::new(ModelWeights::random(&cfg, QuantScheme::Q8_0, 3));
    set2.bench("generate([4 prompt : 8 out], Q8_0)", || {
        engine.generate(&[1, 2, 3, 4], 8, &mut Sampler::greedy(), &mut NativeExec)
    });
    set2.report();
}
