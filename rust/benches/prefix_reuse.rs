//! Bench: prefix-sharing payoff on a templated-prompt serving workload.
//!
//! Serves the same batch of requests — a shared multi-page system-prompt
//! template plus short unique suffixes — through a [`ContinuousBatcher`]
//! with the prefix cache off and on, under the instrumented IMAX cost
//! model, and reports:
//!
//! * prefill tokens executed vs skipped (warm admissions alias the
//!   template's pages and never dispatch their kernels),
//! * the modeled bytes streamed host→LMM and the modeled prefill LOAD
//!   seconds — the paper's transfer-bottleneck quantities — and their
//!   reduction,
//!
//! plus wall-clock timings of a cold vs warm prefill of the same prompt
//! (the functional speedup, independent of the cost model).

use std::time::Instant;

use imax_llm::coordinator::{
    Admitted, ContinuousBatcher, InstrumentedExec, OffloadPolicy, Request,
};
use imax_llm::imax::{ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::engine::NativeExec;
use imax_llm::model::{Engine, ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::util::bench::{BenchSet, JsonMetrics};
use imax_llm::util::report::Table;

const PAGE_SIZE: usize = 16;
const TEMPLATE_PAGES: usize = 2;

fn weights() -> ModelWeights {
    ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 17)
}

fn templated_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let mut prompt: Vec<u32> =
                (0..TEMPLATE_PAGES * PAGE_SIZE).map(|i| 3 + (i % 89) as u32).collect();
            prompt.extend([5 + id as u32, 11, 2 + (id % 7) as u32]);
            Request::new(id, prompt, 4)
        })
        .collect()
}

struct RunStats {
    prefill_tokens_executed: usize,
    prefill_tokens_skipped: usize,
    prefix_hits: usize,
    streamed_bytes: u64,
    prefill_load_s: f64,
    prefill_total_s: f64,
}

fn serve_templated(prefix_cache: bool, n_req: usize) -> RunStats {
    let mut engine = Engine::with_paged_slots(weights(), 4, PAGE_SIZE, None);
    if prefix_cache {
        engine.enable_prefix_cache();
    }
    let mut exec = InstrumentedExec::new(
        NativeExec,
        ImaxDevice::fpga(2),
        OffloadPolicy::new(LmmConfig::new(64)),
        TransferMode::Coalesced,
    );
    let mut batcher = ContinuousBatcher::new(engine, 32, Instant::now());
    let mut queue: std::collections::VecDeque<Request> =
        templated_requests(n_req).into_iter().collect();
    let mut total_prompt_tokens = 0usize;
    while !queue.is_empty() || batcher.n_active() > 0 {
        while let Some(req) = queue.pop_front() {
            total_prompt_tokens += req.prompt.len();
            match batcher.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Deferred(req)) => {
                    total_prompt_tokens -= req.prompt.len();
                    queue.push_front(req);
                    break;
                }
                other => {
                    other.expect("templated requests always fit eventually");
                }
            }
        }
        batcher.decode_round(&mut exec);
    }
    let reuse = batcher.reuse_stats();
    RunStats {
        prefill_tokens_executed: total_prompt_tokens - reuse.prefix_hit_tokens,
        prefill_tokens_skipped: reuse.prefix_hit_tokens,
        prefix_hits: reuse.prefix_hits,
        streamed_bytes: exec.streamed_bytes,
        prefill_load_s: exec.modeled.prefill.load,
        prefill_total_s: exec.modeled.prefill.total(),
    }
}

fn main() {
    let mut set = BenchSet::new("prefix reuse — templated-prompt serving payoff");
    let n_req = if set.is_quick() { 6 } else { 12 };

    let cold = serve_templated(false, n_req);
    let warm = serve_templated(true, n_req);
    assert!(
        warm.prefill_tokens_executed < cold.prefill_tokens_executed,
        "prefix cache must execute strictly fewer prefill tokens"
    );

    let pct = |a: f64, b: f64| if a > 0.0 { 100.0 * (a - b) / a } else { 0.0 };
    let mut t = Table::new(
        "templated serving: prefix cache off vs on (modeled imax:fpga2)",
        &["metric", "off", "on", "reduction"],
    );
    t.row(vec![
        "prefill tokens executed".to_string(),
        cold.prefill_tokens_executed.to_string(),
        warm.prefill_tokens_executed.to_string(),
        format!(
            "{:.0}%",
            pct(
                cold.prefill_tokens_executed as f64,
                warm.prefill_tokens_executed as f64
            )
        ),
    ]);
    t.row(vec![
        "prefill tokens skipped (prefix hits)".to_string(),
        cold.prefill_tokens_skipped.to_string(),
        format!("{} ({} hits)", warm.prefill_tokens_skipped, warm.prefix_hits),
        "-".to_string(),
    ]);
    t.row(vec![
        "modeled bytes streamed host->LMM".to_string(),
        cold.streamed_bytes.to_string(),
        warm.streamed_bytes.to_string(),
        format!("{:.0}%", pct(cold.streamed_bytes as f64, warm.streamed_bytes as f64)),
    ]);
    t.row(vec![
        "modeled prefill LOAD (s)".to_string(),
        format!("{:.6}", cold.prefill_load_s),
        format!("{:.6}", warm.prefill_load_s),
        format!("{:.0}%", pct(cold.prefill_load_s, warm.prefill_load_s)),
    ]);
    t.row(vec![
        "modeled prefill total (s)".to_string(),
        format!("{:.6}", cold.prefill_total_s),
        format!("{:.6}", warm.prefill_total_s),
        format!("{:.0}%", pct(cold.prefill_total_s, warm.prefill_total_s)),
    ]);
    t.print();

    // CI bench-smoke summary: the token counts are deterministic for a
    // fixed shape (the baseline pins the quick shape), the byte/LOAD
    // reductions seed the perf trajectory.
    let shape = if set.is_quick() { "quick" } else { "full" };
    let mut json = JsonMetrics::new(&format!("prefix_reuse_{shape}"));
    json.push(
        "prefill_tokens_executed_cold",
        cold.prefill_tokens_executed as f64,
        "lower",
        set.is_quick(),
    );
    json.push(
        "prefill_tokens_executed_warm",
        warm.prefill_tokens_executed as f64,
        "lower",
        set.is_quick(),
    );
    json.push("prefix_hits_warm", warm.prefix_hits as f64, "higher", set.is_quick());
    json.push("streamed_bytes_cold", cold.streamed_bytes as f64, "lower", true);
    json.push("streamed_bytes_warm", warm.streamed_bytes as f64, "lower", true);
    json.push(
        "streamed_bytes_reduction_pct",
        pct(cold.streamed_bytes as f64, warm.streamed_bytes as f64),
        "higher",
        true,
    );
    json.push("prefill_load_s_warm", warm.prefill_load_s, "lower", true);
    json.write_if_requested().expect("BENCH_JSON path writable");

    // Wall-clock: cold vs warm prefill of one templated prompt (warm
    // re-admissions alias the template pages; the engine is rebuilt per
    // iteration for the cold case via session churn on distinct tokens).
    let prompt: Vec<u32> = templated_requests(1).remove(0).prompt;
    let mut cold_engine = Engine::with_paged_slots(weights(), 2, PAGE_SIZE, None);
    set.bench("prefill: cold (no prefix cache)", || {
        let sess = cold_engine.open_session(Sampler::greedy()).unwrap();
        let logits = cold_engine.prefill_session(&sess, &prompt, 32, &mut NativeExec);
        cold_engine.close_session(sess);
        logits[0]
    });
    let mut warm_engine = Engine::with_paged_slots(weights(), 2, PAGE_SIZE, None);
    warm_engine.enable_prefix_cache();
    set.bench("prefill: warm (template pages aliased)", || {
        let sess = warm_engine.open_session(Sampler::greedy()).unwrap();
        let res = warm_engine
            .try_prefill_session_shared(&sess, &prompt, 32, &mut NativeExec)
            .unwrap();
        warm_engine.close_session(sess);
        res.logits[0]
    });
    set.report();
}
