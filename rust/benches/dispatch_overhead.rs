//! Bench: plan/submit dispatch overhead — keeps the cost of the launch
//! queue abstraction visible next to the eager path. Compares a raw
//! `LaunchQueue` record/submit cycle and a full single-token forward
//! step dispatched through (a) bare eager `NativeExec` (submit is a
//! no-op), (b) the registry's native backend (enum dispatch), and
//! (c/d) the queued instrumented imax backend with and without the
//! double-buffered overlap model.

use imax_llm::model::engine::NativeExec;
use imax_llm::model::graph::{MatvecOp, OpKind, Phase};
use imax_llm::model::{Engine, LinearKind, ModelConfig, ModelWeights, QuantScheme};
use imax_llm::quant::GgmlType;
use imax_llm::runtime::queue::{KernelOp, LaunchQueue};
use imax_llm::runtime::BackendRegistry;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("dispatch overhead — eager vs queued (plan/submit)");

    // Raw queue mechanics: one layer's worth of descriptors + flush.
    let op = MatvecOp {
        kind: OpKind::Linear(LinearKind::QProj),
        layer: Some(0),
        wty: GgmlType::Q8_0,
        rows: 256,
        cols: 256,
    };
    set.bench("launch_queue: record 7 + submit", || {
        let mut q: LaunchQueue<()> = LaunchQueue::new();
        for _ in 0..7 {
            q.record(KernelOp::Linear { op: op.clone(), batch: 1 }, ());
        }
        q.submit().len()
    });

    // Engine-level: a single-token forward step through each dispatch
    // path (reset keeps the KV cache bounded across iterations).
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::random(&cfg, QuantScheme::Q8_0, 3);

    let mut e1 = Engine::new(weights.clone());
    set.bench("forward: NativeExec (eager, submit no-op)", || {
        e1.reset();
        e1.forward(7, Phase::Prefill, true, &mut NativeExec).is_some()
    });

    let mut e2 = Engine::new(weights.clone());
    let mut reg_native = BackendRegistry::build_named("native").expect("native backend");
    set.bench("forward: registry native (enum dispatch)", || {
        e2.reset();
        e2.forward(7, Phase::Prefill, true, &mut reg_native).is_some()
    });

    let mut e3 = Engine::new(weights.clone());
    let mut imax = BackendRegistry::build_named("imax").expect("imax backend");
    set.bench("forward: imax (queued, costed at submit)", || {
        e3.reset();
        e3.forward(7, Phase::Prefill, true, &mut imax).is_some()
    });

    let mut e4 = Engine::new(weights);
    let mut dbuf = BackendRegistry::build_named("imax:dbuf").expect("imax:dbuf backend");
    set.bench("forward: imax:dbuf (queued + overlap model)", || {
        e4.reset();
        e4.forward(7, Phase::Prefill, true, &mut dbuf).is_some()
    });

    set.report();
}
