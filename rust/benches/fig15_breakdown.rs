//! Bench: regenerate paper Fig 15 — prefill/decode execution-time
//! breakdown (EXEC/LOAD/DRAIN/CONF/REGV/RANGE).
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("fig15 — phase breakdown");
    set.bench("breakdown(6 workloads x 2 phases)", exp::fig15);
    set.report();
    exp::fig15().print();
    println!("(series written to reports/fig15_breakdown.csv)");
}
