//! Bench: regenerate paper Fig 12 — PDP (energy) comparison by device.
use imax_llm::harness::experiments as exp;
use imax_llm::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("fig12 — PDP grid");
    let w = imax_llm::harness::workloads::find(
        "1.7b",
        imax_llm::model::QuantScheme::Q8_0,
        16,
        4,
    )
    .unwrap();
    set.bench("eval_workload(1.7B Q8_0 [16:4])", || exp::eval_workload(&w));
    set.report();

    let grid = exp::eval_grid();
    exp::fig12(&grid).print();
    println!("(series written to reports/fig12_pdp.csv)");
}
