//! Bench: streaming-serve teardown — mid-decode cancellation and
//! zero-second deadlines under a tight paged pool with prefix sharing
//! and host swap enabled.
//!
//! The scenario drives a [`ContinuousBatcher`] with a delivery sink
//! attached (every sampled token crosses the streaming boundary) over a
//! templated workload in which a third of the requests are cancelled a
//! couple of rounds after admission and a fifth expire instantly. The
//! gated counters are exact by construction: cancels fire on round
//! indices, not timers, so the values are bit-stable across machines.
//!
//! * `cancel_leak_pages` — pages neither free nor resident in the
//!   prefix cache after the churn drains. The teardown contract (a
//!   cancelled or expired request releases exactly its non-shared
//!   pages through the refcount/CoW machinery) says this is 0.
//! * `committed_pages_after_drain` — leaked admission budget; also 0.
//! * `audit_findings` — the static analyzers run live over the whole
//!   churn (the backend is wrapped in [`AuditExec`], so every forward
//!   step's launch stream passes the plan-time schedule verifier, and
//!   the cross-subsystem invariant auditor runs after every round); a
//!   correct build reports 0.
//!
//! With `BENCH_JSON=path` a machine-readable summary is written for the
//! CI `bench-smoke` job (`scripts/check_bench_regression.py` gates the
//! counters against `BENCH_baseline.json`). The shape is already quick
//! (24 tiny requests), so `IMAX_BENCH_QUICK` changes nothing.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use imax_llm::analysis::{self, AuditExec};
use imax_llm::coordinator::{
    Admitted, CancelHandle, ContinuousBatcher, FinishReason, Request, SessionLog,
};
use imax_llm::model::engine::{Engine, NativeExec};
use imax_llm::model::{ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::util::bench::JsonMetrics;
use imax_llm::util::report::Table;

const N_REQ: usize = 24;

fn main() {
    let weights = ModelWeights::random(&ModelConfig::tiny(), QuantScheme::Q8_0, 13);
    // The oversubscribed serving shape: 3 slots on 8 pages of 4 tokens,
    // prefix sharing and a 6-page host-swap arena.
    let mut engine = Engine::with_paged_slots(weights, 3, 4, Some(8));
    engine.enable_prefix_cache();
    engine.set_kv_swap_capacity(6);
    let total_pages = engine.total_pages();
    let delivered = Arc::new(Mutex::new(0usize));
    let sink_count = delivered.clone();
    let mut b = ContinuousBatcher::new(engine, 8, Instant::now()).with_delivery(Box::new(
        move |_ev| {
            *sink_count.lock().unwrap() += 1;
            true
        },
    ));
    let mut exec = AuditExec::new(NativeExec, true);
    let mut audit_findings = 0usize;

    // Templated prompts (three two-page templates plus a short unique
    // suffix). Roles by id: ≡4 (mod 5) expires instantly; otherwise
    // ≡1 (mod 3) cancels two rounds after admission — n_out ≥ 4 keeps
    // that mid-decode; the rest run to completion.
    let mut handles: Vec<Option<CancelHandle>> = Vec::with_capacity(N_REQ);
    let requests: Vec<Request> = (0..N_REQ)
        .map(|id| {
            let tpl = id % 3;
            let mut prompt: Vec<u32> = (0..8).map(|i| (100 * (tpl + 1) + i) as u32).collect();
            prompt.extend((0..id % 4).map(|i| 1 + ((id * 13 + i * 5) % 50) as u32));
            if id % 5 == 4 {
                handles.push(None);
                Request::new(id, prompt, 1 + id % 6).with_deadline_s(0.0)
            } else if id % 3 == 1 {
                let h = CancelHandle::new();
                handles.push(Some(h.clone()));
                Request::new(id, prompt, 4 + id % 4).with_cancel(h)
            } else {
                handles.push(None);
                Request::new(id, prompt, 1 + id % 6)
            }
        })
        .collect();

    let mut queue: VecDeque<Request> = requests.into_iter().collect();
    let mut done: Vec<SessionLog> = Vec::new();
    let mut pending: Vec<(usize, usize)> = Vec::new(); // (fire_round, id)
    let mut rounds = 0usize;
    while !queue.is_empty() || b.n_active() > 0 {
        rounds += 1;
        assert!(rounds < 10_000, "serve churn wedged");
        pending.retain(|&(fire, id)| {
            if fire <= rounds {
                handles[id].as_ref().unwrap().cancel();
                false
            } else {
                true
            }
        });
        while let Some(req) = queue.pop_front() {
            let id = req.id;
            match b.admit(req, Sampler::greedy(), 0.0, &mut exec) {
                Ok(Admitted::Active) => {
                    if handles[id].is_some() {
                        pending.push((rounds + 2, id));
                    }
                }
                Ok(Admitted::Finished(log)) => done.push(log),
                Ok(Admitted::Deferred(req)) => {
                    queue.push_front(req);
                    break;
                }
                Err(e) => panic!("no request here is oversized: {e}"),
            }
        }
        done.extend(b.decode_round(&mut exec));
        audit_findings += analysis::audit(b.engine(), &b).len();
    }
    audit_findings += exec.findings().len();

    assert_eq!(done.len(), N_REQ, "each request completes exactly once");
    let cancelled: Vec<&SessionLog> =
        done.iter().filter(|l| l.reason == FinishReason::Cancelled).collect();
    let expired = done.iter().filter(|l| l.reason == FinishReason::DeadlineExpired).count();
    let completed = done.iter().filter(|l| l.reason == FinishReason::Completed).count();
    assert!(!cancelled.is_empty() && expired > 0 && completed > 0, "all roles exercised");
    let salvaged: usize = cancelled.iter().map(|l| l.tokens.len()).sum();
    let total_tokens: usize = done.iter().map(|l| l.tokens.len()).sum();
    let events = *delivered.lock().unwrap();
    assert_eq!(events, total_tokens, "every token crossed the delivery sink exactly once");

    let cache = &b.engine().cache;
    let leak = total_pages - cache.free_page_count() - cache.cached_resident_pages();
    let committed = b.committed_pages();

    let mut t = Table::new(
        "streaming serve teardown: cancels + deadlines on an 8-page pool",
        &["metric", "value"],
    );
    t.row(vec![
        "requests (completed / cancelled / expired)".to_string(),
        format!("{completed} / {} / {expired}", cancelled.len()),
    ]);
    t.row(vec!["rounds to drain".to_string(), rounds.to_string()]);
    t.row(vec![
        "tokens delivered (salvaged by cancels)".to_string(),
        format!("{total_tokens} ({salvaged})"),
    ]);
    t.row(vec!["pages leaked after drain".to_string(), leak.to_string()]);
    t.row(vec!["committed pages after drain".to_string(), committed.to_string()]);
    t.row(vec!["audit findings (schedule + invariants)".to_string(), audit_findings.to_string()]);
    t.print();

    let mut json = JsonMetrics::new("serve_stream");
    json.push("cancel_leak_pages", leak as f64, "lower", true);
    json.push("committed_pages_after_drain", committed as f64, "lower", true);
    json.push("audit_findings", audit_findings as f64, "lower", true);
    json.push("cancelled_requests", cancelled.len() as f64, "higher", false);
    json.push("expired_requests", expired as f64, "higher", false);
    json.push("salvaged_tokens", salvaged as f64, "higher", false);
    json.push("rounds_to_drain", rounds as f64, "lower", false);
    json.write_if_requested().expect("BENCH_JSON path writable");
}
