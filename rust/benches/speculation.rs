//! Bench: speculative decoding — what the draft/verify loop buys on the
//! paper's LOAD-bound decode regime.
//!
//! Vanilla decode streams every offloaded weight for one token of useful
//! work. With `--speculate k`, a prompt-lookup n-gram drafter proposes
//! up to k continuation tokens and one batched verify ubatch prices the
//! whole draft at a single weight stream, so every accepted token
//! divides the per-round streamed bytes. This bench serves a templated
//! workload (repetitive prompt spans, the shape where prompt-lookup
//! drafting wins) through a [`ContinuousBatcher`] twice — speculation
//! off and k=4 — under the instrumented IMAX cost model and compares:
//!
//! * decode-phase modeled bytes streamed host→LMM per emitted token
//!   (the tentpole metric: strictly lower with speculation),
//! * decode rounds to drain the same workload,
//! * acceptance: accepted tokens per verify pass and the draft accept
//!   rate.
//!
//! Greedy verification is bit-identical to vanilla decode, so the token
//! streams must match exactly. The shape is already quick (2-layer
//! 16-vocab model, 3 requests), so `IMAX_BENCH_QUICK` changes nothing.
//!
//! With `BENCH_JSON=path` a machine-readable summary is written for the
//! CI `bench-smoke` job (`scripts/check_bench_regression.py` gates the
//! deterministic counters against `BENCH_baseline.json`).

use std::time::Instant;

use imax_llm::coordinator::{
    Admitted, ContinuousBatcher, InstrumentedExec, OffloadPolicy, Request, SessionLog,
};
use imax_llm::harness::workloads::templated_prompt;
use imax_llm::imax::{ImaxDevice, LmmConfig, TransferMode};
use imax_llm::model::engine::NativeExec;
use imax_llm::model::{DrafterSpec, Engine, ModelConfig, ModelWeights, QuantScheme, Sampler};
use imax_llm::util::bench::JsonMetrics;
use imax_llm::util::report::Table;

const SPECULATE: usize = 4;
const N_REQ: usize = 3;
const PROMPT_LEN: usize = 48;
const N_OUT: usize = 24;

/// 16-token vocabulary: greedy decode revisits tokens within a few
/// steps, so the trailing gram of the history re-occurs and the drafter
/// has material to work with — the same boilerplate-heavy regime the
/// templated prompts model on real vocabularies.
fn cfg() -> ModelConfig {
    ModelConfig {
        name: "spec-bench",
        n_layers: 2,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        d_ffn: 128,
        vocab_size: 16,
        qk_norm: true,
        rope_theta: 1e4,
        rms_eps: 1e-6,
        max_seq_len: 128,
    }
}

fn weights() -> ModelWeights {
    ModelWeights::random(&cfg(), QuantScheme::Q8_0, 29)
}

struct RunStats {
    tokens: Vec<Vec<u32>>,
    /// Modeled operand bytes streamed host→LMM after the prefill
    /// boundary (decode + verify traffic only).
    decode_streamed_bytes: u64,
    decode_rounds: usize,
    total_out_tokens: usize,
    verify_calls: usize,
    draft_tokens: usize,
    draft_accepted: usize,
}

fn run(speculate: usize) -> RunStats {
    let mut exec = InstrumentedExec::new(
        NativeExec,
        ImaxDevice::fpga(2),
        OffloadPolicy::new(LmmConfig::new(64)),
        TransferMode::Coalesced,
    );
    let mut b = ContinuousBatcher::new(Engine::with_slots(weights(), 4), 32, Instant::now());
    if speculate > 0 {
        b = b.with_speculation(speculate, DrafterSpec::default());
    }
    for id in 0..N_REQ {
        let req = Request::new(id, templated_prompt(id, PROMPT_LEN, cfg().vocab_size), N_OUT);
        assert!(matches!(
            b.admit(req, Sampler::greedy(), 0.0, &mut exec),
            Ok(Admitted::Active)
        ));
    }
    // Settle admission-time prefill into its own round so everything
    // past this boundary is decode/verify traffic.
    exec.round_boundary();
    let prefill_bytes = exec.streamed_bytes;
    let prefill_rounds = exec.rounds.len();
    let mut logs: Vec<SessionLog> = Vec::new();
    while b.n_active() > 0 {
        logs.extend(b.decode_round(&mut exec));
    }
    logs.sort_by_key(|l| l.id);
    RunStats {
        tokens: logs.iter().map(|l| l.tokens.clone()).collect(),
        decode_streamed_bytes: exec.streamed_bytes - prefill_bytes,
        decode_rounds: exec.rounds.len() - prefill_rounds,
        total_out_tokens: logs.iter().map(|l| l.tokens.len()).sum(),
        verify_calls: logs.iter().map(|l| l.verify_calls).sum(),
        draft_tokens: logs.iter().map(|l| l.draft_tokens).sum(),
        draft_accepted: logs.iter().map(|l| l.draft_accepted).sum(),
    }
}

fn main() {
    let vanilla = run(0);
    let spec = run(SPECULATE);
    assert_eq!(
        vanilla.tokens, spec.tokens,
        "speculative decode must be bit-identical to vanilla"
    );
    assert!(spec.verify_calls > 0, "templated workload must trigger drafting");
    // Every emitted token is an accepted token (verification is exact),
    // so bytes per emitted token IS bytes per accepted token.
    let bpt = |r: &RunStats| r.decode_streamed_bytes as f64 / r.total_out_tokens as f64;
    let (bpt_vanilla, bpt_spec) = (bpt(&vanilla), bpt(&spec));
    assert!(
        bpt_spec < bpt_vanilla,
        "speculation must stream fewer modeled bytes per accepted token \
         ({bpt_spec:.0} vs {bpt_vanilla:.0})"
    );
    let accepted_per_verify =
        (spec.draft_accepted + spec.verify_calls) as f64 / spec.verify_calls as f64;
    let accept_rate = spec.draft_accepted as f64 / spec.draft_tokens.max(1) as f64;

    let mut t = Table::new(
        "speculative decoding: templated prompts, greedy, k=4 vs vanilla \
         (modeled imax:fpga2)",
        &["metric", "vanilla", "speculate-4"],
    );
    t.row(vec![
        "decode rounds to drain".to_string(),
        vanilla.decode_rounds.to_string(),
        spec.decode_rounds.to_string(),
    ]);
    t.row(vec![
        "decode-phase bytes streamed host->LMM".to_string(),
        vanilla.decode_streamed_bytes.to_string(),
        spec.decode_streamed_bytes.to_string(),
    ]);
    t.row(vec![
        "bytes streamed per accepted token".to_string(),
        format!("{bpt_vanilla:.0}"),
        format!("{bpt_spec:.0}"),
    ]);
    t.row(vec![
        "verify passes / drafted / accepted".to_string(),
        "-".to_string(),
        format!("{} / {} / {}", spec.verify_calls, spec.draft_tokens, spec.draft_accepted),
    ]);
    t.row(vec![
        "accepted tokens per verify pass".to_string(),
        "1 (by definition)".to_string(),
        format!("{accepted_per_verify:.2}"),
    ]);
    t.row(vec![
        "draft accept rate".to_string(),
        "-".to_string(),
        format!("{:.0}%", 100.0 * accept_rate),
    ]);
    t.print();

    let mut json = JsonMetrics::new("speculation");
    json.push("decode_rounds_spec0", vanilla.decode_rounds as f64, "lower", false);
    json.push("decode_rounds_spec4", spec.decode_rounds as f64, "lower", true);
    json.push("streamed_bytes_per_token_spec0", bpt_vanilla, "lower", false);
    json.push("streamed_bytes_per_token_spec4", bpt_spec, "lower", true);
    json.push(
        "bytes_per_token_ratio_spec0_over_spec4",
        bpt_vanilla / bpt_spec,
        "higher",
        true,
    );
    json.push("accepted_tokens_per_verify", accepted_per_verify, "higher", true);
    json.push("draft_accept_rate", accept_rate, "higher", false);
    json.write_if_requested().expect("BENCH_JSON path writable");
}
