#!/usr/bin/env python3
"""Merge bench JSON summaries and gate them against a committed baseline.

Each bench binary (run with BENCH_JSON=<path>) writes::

    {"bench": "<name>", "metrics": {"<metric>": {"value": .., "better":
     "lower"|"higher", "check": true|false}}}

This script namespaces every metric as ``<bench>/<metric>``, merges the
given files into one summary (``--out``, uploaded as the CI artifact that
seeds the perf trajectory), then compares against the baseline:

* a metric is *gated* only when both the baseline entry and the current
  entry have ``check: true`` (wall-clock metrics ride along as
  informational trajectory points);
* band gate (default): ``better: lower`` fails when current > baseline
  * (1 + tolerance), ``better: higher`` fails when current < baseline
  * (1 - tolerance);
* floor gate: a baseline entry carrying ``"floor": x`` gates on the
  absolute threshold instead — current must sit on the good side of
  ``x`` (``better: higher`` fails below it, ``better: lower`` above
  it), and the recorded baseline value is trajectory-only. Used for
  ratio metrics (``kv_quant/*``) whose exact value may shift as bench
  shapes evolve but whose claimed win must never drop below the
  paper's floor, and for the multi-tenant serving gates
  (``multi_tenant/*``: WFQ rank gain, adaptive-controller steps, and
  SLO attainment on the committed scenario replay) where the floor is
  the contract and the recorded value is machine-dependent timing;
* a metric only the *current* side has is reported but never fails — a
  new bench starts recording before it starts gating. A baseline value
  of null likewise records without gating (used to stage metrics whose
  first real value is measured by CI itself);
* a *gated* baseline metric missing from the run (or present with a
  null value) is a hard failure — a dropped or renamed bench must not
  silently shrink the gate.

Exit status 1 on any regression or missing gated metric, 0 otherwise;
every failure is collected and reported, not just the first. Stdlib
only.
"""

import argparse
import json
import sys


def gate_fails(better, bval, cval, tolerance, floor=None):
    """Per-metric gate: True when current value ``cval`` regresses.

    Band gate (default): ``cval`` beyond the one-sided tolerance band
    around baseline ``bval``, direction given by ``better``. Floor gate
    (``floor`` is not None): ``cval`` on the bad side of the absolute
    threshold, ``bval`` ignored (it may even be None for a staged
    metric whose trajectory value is still unmeasured).
    """
    if floor is not None:
        return cval < floor if better == "higher" else cval > floor
    if better == "lower":
        return cval > bval * (1.0 + tolerance)
    return cval < bval * (1.0 - tolerance)


def load_metrics(path):
    """Return {namespaced_name: entry} for one bench summary file."""
    with open(path) as f:
        doc = json.load(f)
    bench = doc.get("bench", "unknown")
    out = {}
    for name, entry in doc.get("metrics", {}).items():
        out[f"{bench}/{name}"] = entry
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_baseline.json")
    ap.add_argument("--current", nargs="+", required=True, help="bench summary files")
    ap.add_argument("--out", help="write the merged current summary here")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.20,
        help="relative regression allowed before failing (default 0.20)",
    )
    args = ap.parse_args()

    current = {}
    for path in args.current:
        for name, entry in load_metrics(path).items():
            if name in current:
                print(f"warning: duplicate metric {name} (keeping the first)")
                continue
            current[name] = entry

    # Write the merged summary first so the artifact survives a failing
    # gate (the trajectory should record regressions too).
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"metrics": current}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote merged summary to {args.out}")

    with open(args.baseline) as f:
        baseline = json.load(f).get("metrics", {})

    failures = []
    width = max((len(n) for n in set(current) | set(baseline)), default=10)
    print(f"\n{'metric':<{width}}  {'baseline':>14}  {'current':>14}  verdict")
    for name in sorted(set(current) | set(baseline)):
        cur = current.get(name)
        base = baseline.get(name)
        floor = base.get("floor") if base is not None else None
        if cur is None:
            bval = base.get("value")
            if base.get("check", False) and (bval is not None or floor is not None):
                bshow = "null" if bval is None else f"{bval:.6g}"
                print(f"{name:<{width}}  {bshow:>14}  {'-':>14}  MISSING (gated)")
                failures.append((name, bval, None, base.get("better", "lower")))
            else:
                print(f"{name:<{width}}  {bval!s:>14}  {'-':>14}  missing from run")
            continue
        cval = cur.get("value")
        if base is None or (base.get("value") is None and floor is None):
            shown = "null" if cval is None else f"{float(cval):.6g}"
            print(f"{name:<{width}}  {'-':>14}  {shown:>14}  recorded (no gate)")
            continue
        bval = None if base.get("value") is None else float(base["value"])
        bshow = "null" if bval is None else f"{bval:.6g}"
        gated = base.get("check", False) and cur.get("check", False)
        better = base.get("better", cur.get("better", "lower"))
        if cval is None:
            if gated:
                print(f"{name:<{width}}  {bshow:>14}  {'null':>14}  MISSING (gated)")
                failures.append((name, bval, None, better))
            else:
                print(f"{name:<{width}}  {bshow:>14}  {'null':>14}  informational")
            continue
        cval = float(cval)
        if not gated:
            print(f"{name:<{width}}  {bshow:>14}  {cval:>14.6g}  informational")
            continue
        bad = gate_fails(better, bval, cval, args.tolerance, floor)
        if bad:
            verdict = "REGRESSION"
        elif floor is not None:
            verdict = f"ok (floor {floor:g})"
        else:
            verdict = "ok"
        print(f"{name:<{width}}  {bshow:>14}  {cval:>14.6g}  {verdict}")
        if bad:
            failures.append((name, bval, cval, better))

    if failures:
        print(
            f"\n{len(failures)} metric(s) regressed beyond {args.tolerance:.0%}, "
            "fell through a floor, or went missing:"
        )
        for name, bval, cval, better in failures:
            bshow = "null" if bval is None else f"{bval:.6g}"
            if cval is None:
                print(f"  {name}: baseline {bshow} -> missing from run (better: {better})")
            else:
                print(f"  {name}: baseline {bshow} -> current {cval:.6g} (better: {better})")
        return 1
    print("\nno gated regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
